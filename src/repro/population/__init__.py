"""Population-scale virtual-client sampling behind the Participation
protocol (DESIGN.md §8).

The materialized engine holds every worker in the ``(n, ...)`` state; this
package scales the *declared* world past memory: a :class:`Population` of
``prod(cells)`` virtual clients, a hierarchical per-round sampler pure in
``(seed, round)``, hydrate/fold-back between a single-replica
:class:`ServerState` and the existing ``(k, ...)`` engine, and the
:class:`Participation` protocol unifying the static topology masks, the
elastic runtime masks, and the sampler.  Entry point:
``HSGD(..., EngineConfig(population=...))`` then :meth:`HSGD.run_sampled`.
"""
from repro.population.engine import (ParticipationLedger, PopulationEngine,
                                     ServerState)
from repro.population.participation import (ComposedParticipation,
                                            ElasticParticipation,
                                            FullParticipation, Participation,
                                            SampledParticipation,
                                            StaticParticipation, compose)
from repro.population.sampler import (Draw, HierarchicalSampler, Population,
                                      PopulationLike, default_client_sizes,
                                      make_population)

__all__ = [
    "Population", "PopulationLike", "make_population", "Draw",
    "HierarchicalSampler", "default_client_sizes",
    "Participation", "FullParticipation", "StaticParticipation",
    "ElasticParticipation", "SampledParticipation", "ComposedParticipation",
    "compose",
    "PopulationEngine", "ServerState", "ParticipationLedger",
]
