"""The Participation protocol: ONE surface for "who takes part".

PRs 2–8 grew three participation-like surfaces, each with its own shape:

* ``Topology.participants(event)`` — *static* per-event participation (a
  grouped topology's partial-group events);
* the runtime's elastic masks — *dynamic* per-round participation
  (``SimClock.sync`` returns who made the barrier);
* caller-supplied masks on :meth:`HSGD.step`.

The population layer would have been a fourth.  This module instead names
the protocol they all implement — three hooks at three temporal scopes —
and adapts each existing surface onto it; ``HSGD.run_rounds`` consults the
composed protocol object instead of reaching into the clock directly, and
the population engine pins a :class:`SampledParticipation` per round.

Hooks
-----
``event_mask(event)``
    Static: which worker slots an event's aggregate *replaces*, fixed per
    event kind (compiled into the jitted round body — this is what
    ``Topology.participants`` has always been).
``round_mask(event)``
    Dynamic: which slots made THIS round's barrier.  A consuming call —
    invoked at most once per executed sync (the elastic adapter advances
    its clock) — whose result routes the round through the masked executor
    variant (drop semantics: masked slots neither contribute to nor receive
    the aggregate).
``draw(round_index)``
    Population: which *virtual clients* occupy the slots this round, pure
    in ``(seed, round)``; None means the slots ARE the workers (the
    materialized regime).
"""
from __future__ import annotations

import abc
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.population.sampler import Draw, HierarchicalSampler, Population


class Participation(abc.ABC):
    """Protocol base: every hook defaults to "no restriction" so adapters
    override only the scope they own."""

    def event_mask(self, event) -> Optional[np.ndarray]:
        return None

    def round_mask(self, event) -> Optional[np.ndarray]:
        return None

    def draw(self, round_index: int) -> Optional[Draw]:
        return None

    def describe(self) -> Dict:
        return {"kind": type(self).__name__}


class FullParticipation(Participation):
    """Everyone, always — the protocol's identity element."""


class StaticParticipation(Participation):
    """Adapter over ``Topology.participants(event)`` (the static scope)."""

    def __init__(self, topology):
        self.topology = topology

    def event_mask(self, event) -> Optional[np.ndarray]:
        return self.topology.participants(event)

    def describe(self) -> Dict:
        return {"kind": "static", "topology": type(self.topology).__name__}


class ElasticParticipation(Participation):
    """Adapter over a live :class:`~repro.runtime.SimClock`: ``round_mask``
    closes the barrier (``clock.sync`` — consuming, advances simulated
    time) and returns who the deadline policy admitted."""

    def __init__(self, clock):
        self.clock = clock

    def round_mask(self, event) -> Optional[np.ndarray]:
        return self.clock.sync(event)

    def describe(self) -> Dict:
        return {"kind": "elastic", "policy": repr(self.clock.model.policy)}


class SampledParticipation(Participation):
    """The population sampler behind the protocol.  ``draw`` is pure in
    ``(seed, round)``; ``round_mask`` masks the round's *empty slots*
    (drawn clients that never responded) out of every sync, composing the
    sampler with the existing elastic-drop machinery."""

    def __init__(self, population: Population,
                 group_sizes: Tuple[int, ...],
                 round_index: Optional[int] = None):
        self.population = population
        self.sampler = HierarchicalSampler(population, group_sizes)
        self._pinned: Optional[Draw] = (
            None if round_index is None else self.sampler.draw(round_index))

    def draw(self, round_index: int) -> Draw:
        if self._pinned is not None and \
                self._pinned.round_index == round_index:
            return self._pinned
        return self.sampler.draw(round_index)

    def round_mask(self, event) -> Optional[np.ndarray]:
        d = self._pinned
        if d is None:
            return None
        act = d.active
        return None if act.all() else act.copy()

    def describe(self) -> Dict:
        return {"kind": "sampled", **self.population.describe()}


class ComposedParticipation(Participation):
    """AND of masks, first non-None draw.  ``round_mask`` calls every
    member exactly once (members may consume — the elastic adapter does)."""

    def __init__(self, parts: Sequence[Participation]):
        self.parts = tuple(parts)

    @staticmethod
    def _and(masks) -> Optional[np.ndarray]:
        masks = [m for m in masks if m is not None]
        if not masks:
            return None
        out = np.asarray(masks[0], bool).copy()
        for m in masks[1:]:
            out &= np.asarray(m, bool)
        return out

    def event_mask(self, event) -> Optional[np.ndarray]:
        return self._and(p.event_mask(event) for p in self.parts)

    def round_mask(self, event) -> Optional[np.ndarray]:
        return self._and([p.round_mask(event) for p in self.parts])

    def draw(self, round_index: int) -> Optional[Draw]:
        for p in self.parts:
            d = p.draw(round_index)
            if d is not None:
                return d
        return None

    def describe(self) -> Dict:
        return {"kind": "composed",
                "parts": [p.describe() for p in self.parts]}


def compose(*parts: Optional[Participation]) -> Participation:
    """Compose, dropping Nones; 0 parts → FullParticipation, 1 part → it."""
    live = [p for p in parts if p is not None]
    if not live:
        return FullParticipation()
    if len(live) == 1:
        return live[0]
    return ComposedParticipation(live)
