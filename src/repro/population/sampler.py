"""Population-scale virtual-client sampling (ROADMAP "millions of users").

The engine's ``(n, ...)`` state materializes every worker, so n is bounded
by memory.  This module introduces the *population regime*: a declared
universe of ``prod(cells)`` virtual clients organized as a uniform tree that
mirrors the topology's hierarchy, from which each sampling round draws the
``k = topology.n`` active clients — **hierarchically** (sample cells at each
level, then clients per cell), so a two-level draw is "pick N_1 of C_1
cells, then N_2 of C_2 clients inside each picked cell".

Purity contract (same as :mod:`repro.runtime.stragglers`): every draw is a
counter-based function of ``(seed, round, level, cell-path)`` — calling
:meth:`HierarchicalSampler.draw` twice for the same round returns identical
draws, two populations with different seeds are independent, and NOTHING of
size O(population) is ever materialized (draws are rejection-sampled, so
cost and memory scale with k, not with ``prod(cells)``).

Because cell picks are sorted, the slot layout is cell-major and static: the
j-th engine slot always belongs to the j-th drawn cell of its level, so the
*topology over slots* never changes (one jit cache for every round) while
the *clients behind the slots* are redrawn every round — exactly the
paper's Theorem-2 random regrouping, now drawn from a population instead of
permuting a materialized n (see :meth:`Draw.grouping`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

import numpy as np

from repro.core.grouping import Grouping

_SALT = 0x90BC11  # population-layer namespace (stragglers use 0x5712A6)


def _rng(seed: int, *ctx: int) -> np.random.Generator:
    """Counter-based generator: pure in (seed, *ctx), independent across
    distinct contexts."""
    return np.random.default_rng([_SALT, int(seed)] + [int(c) for c in ctx])


def _draw_without_replacement(rng: np.random.Generator, n: int,
                              k: int) -> np.ndarray:
    """k distinct ints from range(n), sorted.  O(k) memory: the population
    regime has n up to 10^6+ per level and k tiny, where materializing
    ``rng.choice(n, ..., replace=False)``'s internal permutation would cost
    O(n); rejection sampling keeps the draw bounded by the slot count."""
    assert 0 <= k <= n, (k, n)
    if k == n:
        return np.arange(n, dtype=np.int64)
    if 4 * k >= n:  # dense draw: the permutation is the cheap path
        return np.sort(rng.choice(n, size=k, replace=False).astype(np.int64))
    picked: set = set()
    while len(picked) < k:
        for c in rng.integers(0, n, size=k - len(picked)):
            picked.add(int(c))
    return np.sort(np.fromiter(picked, np.int64, len(picked)))


@dataclasses.dataclass(frozen=True)
class Draw:
    """One round's resolved participation: which virtual clients occupy the
    k engine slots.  ``client_ids[j] == -1`` marks an *empty slot* — the
    sampled client never responded (availability) — which the engine masks
    out of every sync and weighs 0 at fold-back."""
    round_index: int
    client_ids: np.ndarray   # (k,) int64 leaf ids into the population; -1 empty
    paths: np.ndarray        # (k, M) per-level cell indices of each slot

    @property
    def k(self) -> int:
        return len(self.client_ids)

    @property
    def active(self) -> np.ndarray:
        """(k,) bool — slots whose client responded."""
        return self.client_ids >= 0

    def grouping(self) -> Grouping:
        """The round's Theorem-2 regrouping of slots by drawn top-level cell
        (slot-side it is always the same contiguous grouping — the
        *membership* behind it is what the draw randomizes)."""
        return Grouping.from_labels(self.paths[:, 0])

    def num_cells(self) -> int:
        return len(np.unique(self.paths[:, 0]))


@dataclasses.dataclass(frozen=True)
class Population:
    """Declarative population spec (resolves via :func:`make_population`;
    binds to an engine through ``EngineConfig(population=...)``).

    cells: per-level fanout ``(C_1, ..., C_M)`` mirroring the topology's
        ``group_sizes (N_1, ..., N_M)``; the population is the
        ``prod(cells)`` leaves of the uniform tree and a round draws N_l of
        C_l branches at each level (so ``C_l >= N_l`` is required).
    seed: sampler namespace — draws are pure in ``(seed, round)``.
    weighting: fold-back client weights — ``"uniform"`` or ``"size"``
        (dataset-size proportional; sizes come from the data layer, e.g.
        :meth:`repro.data.federated.PopulationShards.client_size`).
    p_available: probability a *drawn* client responds (pure per
        ``(seed, round, client)``); non-respondents become empty slots.
    staleness_decay: per-missed-barrier fold-back discount for slots the
        elastic runtime dropped from the round's last admitted sync
        (``SimClock.last_admitted``); 1.0 disables.
    fold: ``"dense"`` (weighted mean over slots), ``"nonzero"`` (per-entry
        nonzero-mask weighted mean — the fed-dropout idiom for sparse/topk
        payloads, zero-denominator entries keep the server value), or
        ``"auto"`` (nonzero iff the engine's wire codec is sparse).
    """
    cells: Tuple[int, ...]
    seed: int = 0
    weighting: str = "uniform"
    p_available: float = 1.0
    staleness_decay: float = 1.0
    fold: str = "auto"

    def __post_init__(self):
        object.__setattr__(self, "cells", tuple(int(c) for c in self.cells))
        assert all(c >= 1 for c in self.cells), self.cells
        assert self.weighting in ("uniform", "size"), self.weighting
        assert 0.0 <= self.p_available <= 1.0, self.p_available
        assert 0.0 <= self.staleness_decay <= 1.0, self.staleness_decay
        assert self.fold in ("auto", "dense", "nonzero"), self.fold

    @property
    def size(self) -> int:
        return math.prod(self.cells)

    def describe(self) -> dict:
        return {"population": self.size, "cells": list(self.cells),
                "seed": self.seed, "weighting": self.weighting,
                "p_available": self.p_available,
                "staleness_decay": self.staleness_decay, "fold": self.fold}


PopulationLike = Optional[object]  # None | Population | (C_1, ..., C_M)


def make_population(spec: PopulationLike = None) -> Optional[Population]:
    """None → None; a Population passes through; a tuple/list of per-level
    fanouts (or a bare int for single-level) builds a default Population."""
    if spec is None or isinstance(spec, Population):
        return spec
    if isinstance(spec, int):
        return Population(cells=(spec,))
    if isinstance(spec, (tuple, list)):
        return Population(cells=tuple(spec))
    raise TypeError(f"population spec must be None, a Population, an int or "
                    f"a per-level fanout tuple; got {spec!r}")


class HierarchicalSampler:
    """Draws ``k = prod(group_sizes)`` clients per round from a
    :class:`Population` whose tree mirrors ``group_sizes`` level for level."""

    def __init__(self, population: Population,
                 group_sizes: Tuple[int, ...]):
        cells, gs = population.cells, tuple(int(g) for g in group_sizes)
        if len(cells) != len(gs):
            raise ValueError(
                f"population cells {cells} must declare one fanout per "
                f"hierarchy level (topology has {len(gs)} levels "
                f"{gs}); e.g. a two-level (N, K) topology over a "
                f"1000x1000-client population is cells=(1000, 1000)")
        for l, (c, g) in enumerate(zip(cells, gs), start=1):
            if c < g:
                raise ValueError(
                    f"level-{l} draw needs {g} of {c} population cells — "
                    f"cells[{l - 1}] must be >= group_sizes[{l - 1}]")
        self.population = population
        self.group_sizes = gs
        self.k = math.prod(gs)
        # leaf id = mixed-radix path over the population fanouts
        self._radix = np.array(
            [math.prod(cells[l + 1:]) for l in range(len(cells))], np.int64)

    def draw(self, round_index: int) -> Draw:
        """Pure in ``(population.seed, round_index)``."""
        pop, r = self.population, int(round_index)
        prefixes: list = [()]
        for l, (c, g) in enumerate(zip(pop.cells, self.group_sizes)):
            nxt = []
            for p in prefixes:
                picks = _draw_without_replacement(
                    _rng(pop.seed, 1, r, l, *p), c, g)
                nxt += [p + (int(i),) for i in picks]
            prefixes = nxt
        paths = np.asarray(prefixes, np.int64).reshape(self.k, -1)
        ids = paths @ self._radix
        if pop.p_available < 1.0:
            # availability is applied post-draw (the sampled device never
            # responded), so the draw itself stays O(k)
            u = np.array([_rng(pop.seed, 2, r, int(c) + 1).random()
                          for c in ids])
            ids = np.where(u < pop.p_available, ids, np.int64(-1))
        return Draw(round_index=r, client_ids=ids, paths=paths)


def default_client_sizes(seed: int = 0, log_mean: float = 5.0,
                         log_sigma: float = 1.0) -> Callable[[int], float]:
    """Default dataset-size law for ``weighting="size"`` when no data layer
    provides one: heavy-tailed lognormal per-client example counts, pure in
    ``(seed, client_id)`` (``PopulationShards.client_size`` uses the same
    law so weights and data agree)."""
    def size(client_id: int) -> float:
        if client_id < 0:
            return 0.0
        return float(1 + int(_rng(seed, 3, int(client_id) + 1)
                             .lognormal(log_mean, log_sigma)))
    return size
