"""Hydrate → run → fold-back: the population-regime round loop.

A *sampling round* is one global period G of the bound topology:

1. ``sampler.draw(r)`` picks the k = topology.n virtual clients (pure in
   ``(seed, r)`` — see :mod:`repro.population.sampler`);
2. **hydrate**: the server model broadcasts into the existing ``(k, ...)``
   engine state (virtual clients are stateless between rounds — error
   feedback and probe buffers reset; optimizer state, including schedule
   counters, carries over from the server so trajectories line up with the
   materialized engine);
3. the UNCHANGED round executor runs the G steps — on an *inner* engine
   whose topology is the user's with level-1 events removed, so sub-global
   levels sync exactly as declared while the global aggregation is
   deferred to the fold-back (that is what makes non-uniform fold weights
   meaningful: slots still differ at the boundary);
4. **fold-back**: the server model absorbs the slot results with
   dataset-size × staleness weights.  Two modes share one kernel:
   ``dense`` takes the weighted mean of slot params (with uniform weights
   this is bit-for-bit the aggregator's own level-1 mean — tested), and
   ``nonzero`` applies the per-entry nonzero-mask weighted mean to slot
   *deltas* (the fed-dropout idiom: an entry only the sparse/topk codec's
   selected coordinates touched averages over the slots that moved it, and
   a zero-denominator entry — nobody moved it — keeps the server value via
   :func:`~repro.core.aggregators.denominator_floor`, never NaN).

Peak state memory is bounded by k: the population exists only as the
sampler's arithmetic and the (sparsely grown) participation ledger.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregators import denominator_floor
from repro.core.hierarchy import HierarchySpec
from repro.core.topology import UniformTopology
from repro.population.participation import SampledParticipation
from repro.population.sampler import (Draw, HierarchicalSampler, Population,
                                      default_client_sizes)


class _SubGlobalTopology(UniformTopology):
    """The bound topology with level-1 events removed: within a sampling
    round the sub-global levels sync exactly as declared, and the global
    aggregation happens at the fold-back instead — on the SAME schedule
    positions the materialized engine would fire level 1 (steps that are
    multiples of G fire nothing in-graph)."""

    def event_at(self, t: int):
        ev = super().event_at(t)
        return None if ev is not None and ev.level == 1 else ev


@dataclasses.dataclass
class ParticipationLedger:
    """Sparse host-side record of who has participated — grows with the
    number of *sampled* clients, never with the population."""
    last_round: Dict[int, int] = dataclasses.field(default_factory=dict)
    counts: Dict[int, int] = dataclasses.field(default_factory=dict)

    def note(self, round_index: int, client_ids: np.ndarray) -> Dict:
        ids = [int(c) for c in client_ids if c >= 0]
        reseen = sum(1 for c in ids if c in self.counts)
        for c in ids:
            self.counts[c] = self.counts.get(c, 0) + 1
            self.last_round[c] = int(round_index)
        return {"reseen": reseen, "unique": len(self.counts)}


@dataclasses.dataclass
class ServerState:
    """The population regime's server model: ONE replica (no worker axis),
    plus the sampling-round counter and the participation ledger."""
    params: Any
    opt_state: Any
    round: int = 0
    ledger: ParticipationLedger = dataclasses.field(
        default_factory=ParticipationLedger)


class PopulationEngine:
    """Binds a plan (:class:`~repro.core.hsgd.HSGD` with
    ``config.population`` set) to the hydrate/run/fold-back loop.  Built
    lazily by :meth:`HSGD.run_sampled`."""

    def __init__(self, plan):
        pop: Population = plan.population
        assert pop is not None, "plan has no population bound"
        topo = plan.topology
        if not isinstance(topo, UniformTopology):
            raise TypeError(
                f"the population regime needs a UniformTopology over the "
                f"k active slots (got {type(topo).__name__}); express "
                f"grouped structure in the population cells instead")
        gs, periods = topo.spec.group_sizes, topo.spec.periods
        self.plan = plan
        self.population = pop
        self.sampler = HierarchicalSampler(pop, gs)
        self.round_steps = int(periods[0])  # G: one sampling round
        # inner topology: the user's with level-1 events REMOVED (not a
        # stretched period, which would let level 2 fire at the global
        # boundary and pre-average the rows) — fold-back IS level 1
        from repro.core.hsgd import HSGD, EngineConfig
        inner_topo = _SubGlobalTopology(HierarchySpec(gs, periods),
                                        aggregator=topo.aggregator)
        self.inner = HSGD(
            plan.loss_fn, plan.optimizer, inner_topo,
            EngineConfig(executor=plan.executor.twin(),
                         comms=plan.comms, runtime=plan.runtime,
                         metrics=plan.metrics,
                         aggregate_opt_state=plan.aggregate_opt_state,
                         jit=plan._jit, accum_steps=plan.accum_steps))
        self._fold_cache: Dict[Tuple, Callable] = {}

    # -- mode resolution -----------------------------------------------------
    @property
    def fold_mode(self) -> str:
        mode = self.population.fold
        if mode != "auto":
            return mode
        codec = getattr(self.plan.comms, "codec", None)
        return "nonzero" if getattr(codec, "name", "") == "topk" else "dense"

    # -- hydrate -------------------------------------------------------------
    def init_server(self, key, model_init: Callable) -> ServerState:
        params0 = model_init(key)
        return ServerState(params=params0,
                           opt_state=self.plan.optimizer.init(params0))

    def hydrate(self, server: ServerState):
        """Broadcast the server model into a fresh placed (k, ...) state."""
        from repro.core.hsgd import HSGDState
        eng, k = self.inner, self.inner.topology.n
        bcast = lambda t: jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), t)
        params = bcast(server.params)
        state = HSGDState(
            params, bcast(server.opt_state), jnp.zeros((), jnp.int32),
            eng.comms.init_state(params) if eng.comms else None,
            eng.metrics.init_buffer(eng.topology) if eng.metrics else None)
        return eng.executor.place(state)

    # -- fold-back -----------------------------------------------------------
    def _fold_fn(self, mode: str, weighted: bool) -> Callable:
        key = (mode, weighted)
        if key in self._fold_cache:
            return self._fold_cache[key]
        from repro.core.topology import SyncEvent
        topo = self.plan.topology
        acc = topo.aggregator.accum_dtype
        ev = SyncEvent(level=1)

        def dense(tree, w):
            # EXACTLY the engine's level-1 aggregate (same reshape-mean,
            # same accumulation dtype — that is what makes the uniform case
            # bitwise with the materialized global sync), then one row
            return jax.tree.map(lambda x: x[0], topo.aggregate(tree, ev,
                                                               mask=w))

        def fold_leaf_nonzero(s, p, w):
            d = p.astype(acc) - s.astype(acc)[None]
            m = (d != 0).astype(acc)
            if w is not None:
                m = m * w.astype(acc).reshape((-1,) + (1,) * (p.ndim - 1))
            num = (d * m).sum(0, dtype=acc)
            den = jnp.maximum(m.sum(0, dtype=acc), denominator_floor(acc))
            return (s.astype(acc) + num / den).astype(s.dtype)

        def fold(server_params, server_opt, params, opt_state, w):
            if mode == "dense":
                new_params = dense(params, w)
            else:
                new_params = jax.tree.map(
                    lambda s, p: fold_leaf_nonzero(s, p, w),
                    server_params, params)
            # moments fold dense (they ride the level-1 sync the same way in
            # the materialized engine); counters are identical across slots
            new_opt = {
                name: (dense(v, w)
                       if name in ("m", "v") and self.plan.aggregate_opt_state
                       else jax.tree.map(lambda p: p[0], v))
                for name, v in opt_state.items()}
            return new_params, new_opt

        fn = jax.jit(fold) if self.plan._jit else fold
        self._fold_cache[key] = fn
        return fn

    def fold_back(self, server: ServerState, state,
                  weights: Optional[np.ndarray]) -> ServerState:
        """Fold the round's (k, ...) results into the server model.  An
        all-zero weight vector (every slot empty) keeps the server exactly
        — the zero-denominator guard's host-side twin."""
        if weights is not None and not np.any(weights > 0):
            return server
        params, opt_state = state.params, state.opt_state
        if self._needs_gather():
            # mesh-sharded state: gather to one device so the fold's
            # reduction order matches the sim executor bit for bit
            params, opt_state = jax.device_get((params, opt_state))
        w = None if weights is None else jnp.asarray(weights, jnp.float32)
        new_params, new_opt = self._fold_fn(self.fold_mode, w is not None)(
            server.params, server.opt_state, params, opt_state, w)
        return dataclasses.replace(server, params=new_params,
                                   opt_state=new_opt)

    def _needs_gather(self) -> bool:
        from repro.core.executors import MeshExecutor
        return isinstance(self.plan.executor, MeshExecutor)

    # -- weights -------------------------------------------------------------
    def round_weights(self, draw: Draw,
                      sizes: Optional[Callable[[int], float]] = None
                      ) -> Tuple[Optional[np.ndarray], Dict]:
        """Fold-back weights = dataset size × staleness × availability.
        Returns None (the bitwise plain-mean path) when every factor is
        trivially uniform."""
        pop = self.population
        active = draw.active
        w = active.astype(np.float64)
        if pop.weighting == "size":
            law = sizes if sizes is not None \
                else default_client_sizes(pop.seed)
            w = w * np.array([law(int(c)) for c in draw.client_ids])
        stale = np.zeros(len(w), np.int64)
        clock = self.inner._last_clock
        if clock is not None and pop.staleness_decay < 1.0 \
                and clock.last_admitted:
            # slots the elastic policy cut from the round's outermost fired
            # barrier carry params one admitted sync behind
            lvl = min(clock.last_admitted)
            stale = (~clock.last_admitted[lvl]).astype(np.int64)
            w = w * (pop.staleness_decay ** stale)
        meta = {"active": int(active.sum()),
                "stale_slots": int((stale > 0).sum())}
        uniform = pop.weighting == "uniform" and active.all() \
            and not (stale > 0).any()
        return (None if uniform else w), meta

    # -- the loop ------------------------------------------------------------
    def run(self, server: ServerState, batch_fn: Callable[[np.ndarray, int],
                                                          Any],
            rounds: int, *, sizes: Optional[Callable[[int], float]] = None,
            eval_every: int = 0,
            eval_fn: Optional[Callable[[ServerState, int], Dict]] = None
            ) -> Tuple[ServerState, List[Dict]]:
        """``batch_fn(client_ids, t)`` -> a batch with leading axis k for
        global step t (k-aligned with ``client_ids``; ids are -1 for empty
        slots).  Returns one history record per sampling round."""
        history: List[Dict] = []
        G = self.round_steps
        for _ in range(int(rounds)):
            r = server.round
            draw = self.sampler.draw(r)
            part = SampledParticipation(self.population,
                                        self.plan.topology.spec.group_sizes,
                                        round_index=r)
            state = self.hydrate(server)
            state, inner_hist = self.inner.run_rounds(
                state, lambda t: batch_fn(draw.client_ids, r * G + t), G,
                participation=part)
            weights, wmeta = self.round_weights(draw, sizes)
            server = self.fold_back(server, state, weights)
            server.round = r + 1
            ledger = server.ledger.note(r, draw.client_ids)
            rec: Dict = {"round": r + 1, "t": (r + 1) * G}
            last = inner_hist[-1] if inner_hist else {}
            rec.update({k: v for k, v in last.items()
                        if k != "t" and isinstance(v, (int, float))})
            # wire_bytes/dropped are per-step channels, and the round's final
            # step is the dropped level-1 slot (0 bytes) — report round totals
            for key in ("wire_bytes", "dropped"):
                if any(key in h for h in inner_hist):
                    rec[key] = sum(h.get(key, 0) for h in inner_hist)
            rec["participation"] = {
                "k": draw.k, "population": self.population.size,
                "cells": draw.num_cells(), **wmeta, **ledger}
            if eval_fn is not None and eval_every \
                    and (server.round % eval_every == 0
                         or server.round == rounds):
                rec.update(eval_fn(server, server.round))
            history.append(rec)
        if self.plan.metrics is not None:
            from repro.obs import validate_record
            for rec in history:
                errs = validate_record(rec)
                if errs:
                    raise ValueError(
                        "metrics-bus violations in run_sampled history at "
                        f"round={rec.get('round')}: " + "; ".join(errs))
        return server, history

    # -- analysis ------------------------------------------------------------
    def audit(self, server: ServerState, batch_fn=None, **kwargs):
        """Audit the sampled round body (the inner engine over one sampling
        round): R1–R6 on exactly the program :meth:`run` dispatches."""
        wrapped = None
        if batch_fn is not None:
            draw = self.sampler.draw(server.round)
            wrapped = lambda t: batch_fn(draw.client_ids, t)
        kwargs.setdefault("T", self.round_steps)
        return self.inner.audit(self.hydrate(server), wrapped, **kwargs)
