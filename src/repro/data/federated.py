"""Non-IID federated data for the paper-experiment reproduction.

The paper partitions CIFAR-10/FEMNIST/CelebA by label across workers
(§6, Appendix E: "The assigned label for each worker is different").  Offline
we generate a K-class Gaussian-mixture classification task and partition it
with the same constructions:

* ``label_shard_partition`` — each worker sees a fixed subset of labels
  (the paper's CIFAR split: group 1 labels {0..4}, group 2 labels {5..9}).
* ``dirichlet_partition``   — label-skew via Dir(alpha) (standard FL benchmark).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np


def make_classification(seed: int, num_classes: int = 10, dim: int = 32,
                        per_class: int = 200, spread: float = 1.2):
    """Gaussian mixture: class c ~ N(mu_c, I). Returns (x, y) arrays."""
    rng = np.random.default_rng(seed)
    mus = rng.normal(size=(num_classes, dim)) * spread
    xs, ys = [], []
    for c in range(num_classes):
        xs.append(mus[c] + rng.normal(size=(per_class, dim)))
        ys.append(np.full(per_class, c))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    perm = rng.permutation(len(y))
    return x[perm], y[perm]


def label_shard_partition(y: np.ndarray, worker_labels: Sequence[Sequence[int]],
                          seed: int = 0) -> List[np.ndarray]:
    """worker_labels[j] = labels assigned to worker j. Returns index lists.
    Samples of a label shared by multiple workers are split evenly."""
    rng = np.random.default_rng(seed)
    owners: Dict[int, List[int]] = {}
    for j, labs in enumerate(worker_labels):
        for lab in labs:
            owners.setdefault(int(lab), []).append(j)
    parts: List[List[int]] = [[] for _ in worker_labels]
    for lab, js in owners.items():
        idx = np.nonzero(y == lab)[0]
        rng.shuffle(idx)
        for k, chunk in enumerate(np.array_split(idx, len(js))):
            parts[js[k]].extend(chunk.tolist())
    return [np.asarray(sorted(p), np.int64) for p in parts]


def dirichlet_partition(y: np.ndarray, n_workers: int, alpha: float,
                        seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    parts: List[List[int]] = [[] for _ in range(n_workers)]
    for c in classes:
        idx = np.nonzero(y == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_workers)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for j, chunk in enumerate(np.split(idx, cuts)):
            parts[j].extend(chunk.tolist())
    return [np.asarray(sorted(p), np.int64) for p in parts]


@dataclasses.dataclass
class FederatedDataset:
    """Per-worker datasets + minibatch sampler with leading worker axis."""
    x: np.ndarray
    y: np.ndarray
    parts: List[np.ndarray]
    seed: int = 0

    @property
    def n_workers(self) -> int:
        return len(self.parts)

    def dominant_labels(self) -> List[int]:
        return [int(np.bincount(self.y[p]).argmax()) for p in self.parts]

    def batch(self, step: int, batch_size: int) -> Dict[str, np.ndarray]:
        """IID minibatch per worker from that worker's shard (paper's SGD)."""
        xs, ys = [], []
        for j, part in enumerate(self.parts):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 613 + j)
            take = rng.integers(0, len(part), size=batch_size)
            xs.append(self.x[part[take]])
            ys.append(self.y[part[take]])
        return {"x": np.stack(xs), "y": np.stack(ys)}

    def full_per_worker(self, cap: int = 512) -> Dict[str, np.ndarray]:
        """Equal-size per-worker eval batches (for divergence measurement)."""
        m = min(cap, min(len(p) for p in self.parts))
        xs = np.stack([self.x[p[:m]] for p in self.parts])
        ys = np.stack([self.y[p[:m]] for p in self.parts])
        return {"x": xs, "y": ys}

    def global_batch(self, cap: int = 2048) -> Dict[str, np.ndarray]:
        idx = np.arange(min(cap, len(self.y)))
        return {"x": self.x[idx], "y": self.y[idx]}
