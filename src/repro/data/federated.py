"""Non-IID federated data for the paper-experiment reproduction.

The paper partitions CIFAR-10/FEMNIST/CelebA by label across workers
(§6, Appendix E: "The assigned label for each worker is different").  Offline
we generate a K-class Gaussian-mixture classification task and partition it
with the same constructions:

* ``label_shard_partition`` — each worker sees a fixed subset of labels
  (the paper's CIFAR split: group 1 labels {0..4}, group 2 labels {5..9}).
* ``dirichlet_partition``   — label-skew via Dir(alpha) (standard FL benchmark).

For the population regime, :class:`PopulationShards` declares the same
mixture task for *millions* of virtual clients without materializing any of
it: per-client labels, dataset sizes and minibatches are all counter-based
functions of ``(seed, client_id, step)``, so memory is O(num_classes × dim)
regardless of the population (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


def make_classification(seed: int, num_classes: int = 10, dim: int = 32,
                        per_class: int = 200, spread: float = 1.2):
    """Gaussian mixture: class c ~ N(mu_c, I). Returns (x, y) arrays."""
    rng = np.random.default_rng(seed)
    mus = rng.normal(size=(num_classes, dim)) * spread
    xs, ys = [], []
    for c in range(num_classes):
        xs.append(mus[c] + rng.normal(size=(per_class, dim)))
        ys.append(np.full(per_class, c))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    perm = rng.permutation(len(y))
    return x[perm], y[perm]


def label_shard_partition(y: np.ndarray, worker_labels: Sequence[Sequence[int]],
                          seed: int = 0, *,
                          n_workers: Optional[int] = None) -> List[np.ndarray]:
    """worker_labels[j] = labels assigned to worker j. Returns index lists.
    Samples of a label shared by multiple workers are split evenly.

    ``n_workers`` (usually the topology's ``n``) cross-checks the partition
    up front — a mismatch used to surface only as a shape error deep in the
    first round."""
    if n_workers is not None and len(worker_labels) != n_workers:
        raise ValueError(
            f"label_shard_partition got {len(worker_labels)} worker label "
            f"sets but the topology has n={n_workers} workers — provide "
            f"exactly one label set per worker")
    present = set(np.unique(y).tolist())
    for j, labs in enumerate(worker_labels):
        missing = [int(l) for l in labs if int(l) not in present]
        if missing:
            raise ValueError(
                f"worker {j} is assigned label(s) {missing} that do not "
                f"occur in y (labels present: {sorted(present)}) — its "
                f"shard would be empty and batch() would fail later")
    rng = np.random.default_rng(seed)
    owners: Dict[int, List[int]] = {}
    for j, labs in enumerate(worker_labels):
        for lab in labs:
            owners.setdefault(int(lab), []).append(j)
    parts: List[List[int]] = [[] for _ in worker_labels]
    for lab, js in owners.items():
        idx = np.nonzero(y == lab)[0]
        rng.shuffle(idx)
        for k, chunk in enumerate(np.array_split(idx, len(js))):
            parts[js[k]].extend(chunk.tolist())
    return [np.asarray(sorted(p), np.int64) for p in parts]


def dirichlet_partition(y: np.ndarray, n_workers: int, alpha: float,
                        seed: int = 0) -> List[np.ndarray]:
    """Label-skew partition: per class, worker proportions ~ Dir(alpha)."""
    if n_workers < 1:
        raise ValueError(
            f"dirichlet_partition needs n_workers >= 1, got {n_workers} — "
            f"pass the topology's n (prod of its group sizes)")
    if not np.isfinite(alpha) or alpha <= 0:
        raise ValueError(
            f"dirichlet_partition needs alpha > 0, got {alpha!r} — the "
            f"Dirichlet concentration must be positive (small alpha ≈ 0.1 "
            f"gives strong label skew, large alpha ≈ 100 is near-IID)")
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    parts: List[List[int]] = [[] for _ in range(n_workers)]
    for c in classes:
        idx = np.nonzero(y == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_workers)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for j, chunk in enumerate(np.split(idx, cuts)):
            parts[j].extend(chunk.tolist())
    return [np.asarray(sorted(p), np.int64) for p in parts]


@dataclasses.dataclass
class FederatedDataset:
    """Per-worker datasets + minibatch sampler with leading worker axis."""
    x: np.ndarray
    y: np.ndarray
    parts: List[np.ndarray]
    seed: int = 0

    @property
    def n_workers(self) -> int:
        return len(self.parts)

    def require_workers(self, n: int) -> "FederatedDataset":
        """Assert this dataset's shard count matches the topology's ``n``.

        Returns self so call sites can chain:
        ``data = FederatedDataset(...).require_workers(topo.n)``."""
        if self.n_workers != n:
            raise ValueError(
                f"dataset has {self.n_workers} worker shards but the "
                f"topology expects n={n} — repartition with exactly one "
                f"shard per worker (e.g. dirichlet_partition(y, {n}, alpha))")
        empty = [j for j, p in enumerate(self.parts) if len(p) == 0]
        if empty:
            raise ValueError(
                f"worker shard(s) {empty} are empty — batch() cannot sample "
                f"from them; use a larger dataset or a less extreme split")
        return self

    def dominant_labels(self) -> List[int]:
        return [int(np.bincount(self.y[p]).argmax()) for p in self.parts]

    def batch(self, step: int, batch_size: int) -> Dict[str, np.ndarray]:
        """IID minibatch per worker from that worker's shard (paper's SGD)."""
        xs, ys = [], []
        for j, part in enumerate(self.parts):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 613 + j)
            take = rng.integers(0, len(part), size=batch_size)
            xs.append(self.x[part[take]])
            ys.append(self.y[part[take]])
        return {"x": np.stack(xs), "y": np.stack(ys)}

    def full_per_worker(self, cap: int = 512) -> Dict[str, np.ndarray]:
        """Equal-size per-worker eval batches (for divergence measurement)."""
        m = min(cap, min(len(p) for p in self.parts))
        xs = np.stack([self.x[p[:m]] for p in self.parts])
        ys = np.stack([self.y[p[:m]] for p in self.parts])
        return {"x": xs, "y": ys}

    def global_batch(self, cap: int = 2048) -> Dict[str, np.ndarray]:
        idx = np.arange(min(cap, len(self.y)))
        return {"x": self.x[idx], "y": self.y[idx]}


# -- population-scale shards (virtual clients, nothing materialized) ----------

_SHARD_SALT = 0xDA7A5D  # data-layer namespace (population sampler: 0x90BC11)


def _shard_rng(seed: int, *ctx: int) -> np.random.Generator:
    return np.random.default_rng([_SHARD_SALT, int(seed)]
                                 + [int(c) for c in ctx])


@functools.lru_cache(maxsize=8)
def _mixture_means(seed: int, num_classes: int, dim: int,
                   spread: float) -> np.ndarray:
    """Class means of the Gaussian-mixture task — drawn exactly like
    :func:`make_classification` so a PopulationShards and a materialized
    dataset with the same seed describe the same task."""
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(num_classes, dim)) * spread).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class PopulationShards:
    """Shard specs for a population of virtual clients, without the data.

    A :class:`FederatedDataset` materializes every worker's shard, which is
    impossible at population scale (10^6+ clients).  PopulationShards
    instead *declares* the per-client shard of the same Gaussian-mixture
    task: which labels a client holds (``client_labels``), how many examples
    it has (``client_size`` — the lognormal law shared with
    ``repro.population.sampler.default_client_sizes`` so fold-back weights
    and data agree), and the minibatch it contributes at a step
    (``batch``).  Everything is a counter-based function of
    ``(seed, client_id, step)``; total memory is the O(num_classes × dim)
    cached class means, independent of ``population``.

    Empty slots (``client_id == -1``, a drawn client that never responded)
    still synthesize a finite batch under the reserved context 0 — the
    engine masks those slots out of every sync and weighs them 0 at
    fold-back, so only finiteness matters, not content.
    """
    population: int
    num_classes: int = 10
    dim: int = 32
    seed: int = 0
    labels_per_client: int = 2
    spread: float = 1.2
    size_log_mean: float = 5.0
    size_log_sigma: float = 1.0

    def __post_init__(self):
        if self.population < 1:
            raise ValueError(f"population must be >= 1, got "
                             f"{self.population}")
        if not 1 <= self.labels_per_client <= self.num_classes:
            raise ValueError(
                f"labels_per_client={self.labels_per_client} must be in "
                f"[1, num_classes={self.num_classes}]")

    @property
    def mus(self) -> np.ndarray:
        return _mixture_means(self.seed, self.num_classes, self.dim,
                              float(self.spread))

    def _check_cid(self, client_id: int) -> int:
        cid = int(client_id)
        if cid >= self.population:
            raise ValueError(
                f"client_id {cid} is outside the declared population of "
                f"{self.population} — the sampler's Population cells must "
                f"multiply to at most this population")
        return cid

    def client_labels(self, client_id: int) -> np.ndarray:
        """The labels this client's shard holds (sorted, pure in
        ``(seed, client_id)``); label-skew analogue of the paper's split."""
        cid = self._check_cid(client_id)
        rng = _shard_rng(self.seed, 1, cid + 1)
        return np.sort(rng.choice(self.num_classes,
                                  size=self.labels_per_client,
                                  replace=False)).astype(np.int32)

    def client_size(self, client_id: int) -> int:
        """Example count of this client's shard; same lognormal law as
        ``default_client_sizes`` (0 for empty slots)."""
        from repro.population.sampler import default_client_sizes
        self._check_cid(client_id)
        return int(default_client_sizes(self.seed, self.size_log_mean,
                                        self.size_log_sigma)(int(client_id)))

    def size_fn(self):
        """The ``sizes`` callable ``HSGD.run_sampled`` expects."""
        from repro.population.sampler import default_client_sizes
        return default_client_sizes(self.seed, self.size_log_mean,
                                    self.size_log_sigma)

    def batch(self, client_ids: Sequence[int], step: int,
              batch_size: int) -> Dict[str, np.ndarray]:
        """Minibatches for the round's k hydrated slots: ``x`` is
        ``(k, B, dim)`` float32, ``y`` is ``(k, B)`` int32."""
        mus = self.mus
        xs, ys = [], []
        for cid in client_ids:
            labels = self.client_labels(cid)
            rng = _shard_rng(self.seed, 2, self._check_cid(cid) + 1,
                             int(step))
            y = labels[rng.integers(0, len(labels), size=batch_size)]
            x = mus[y] + rng.normal(size=(batch_size, self.dim)) \
                            .astype(np.float32)
            xs.append(x)
            ys.append(y)
        return {"x": np.stack(xs).astype(np.float32),
                "y": np.stack(ys).astype(np.int32)}

    def batch_fn(self, batch_size: int
                 ) -> Callable[[np.ndarray, int], Dict[str, np.ndarray]]:
        """The ``batch_fn(client_ids, t)`` callable ``run_sampled`` expects."""
        return lambda client_ids, t: self.batch(client_ids, t, batch_size)

    def describe(self) -> dict:
        return {"population": self.population,
                "num_classes": self.num_classes, "dim": self.dim,
                "seed": self.seed,
                "labels_per_client": self.labels_per_client,
                "spread": self.spread,
                "size_log_mean": self.size_log_mean,
                "size_log_sigma": self.size_log_sigma}
