from repro.data.federated import (FederatedDataset, PopulationShards,
                                  dirichlet_partition, label_shard_partition,
                                  make_classification)
from repro.data.synthetic import TokenStream, synth_lm_batch

__all__ = ["FederatedDataset", "PopulationShards", "dirichlet_partition",
           "label_shard_partition", "make_classification", "TokenStream",
           "synth_lm_batch"]
