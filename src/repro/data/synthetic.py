"""Synthetic LM token pipeline (offline container: no real corpora).

Deterministic, seekable stream: batch t is a pure function of (seed, t), so
multi-host data loading needs no coordination state (each worker slices its
shard by worker id) and restarts are exactly resumable from the step counter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def synth_lm_batch(seed: int, step: int, batch: int, seq_len: int,
                   vocab: int, worker: int = 0) -> Dict[str, jax.Array]:
    """Markov-ish synthetic tokens: learnable structure (next token depends on
    current) so CE decreases during smoke training."""
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), step), worker)
    k1, k2 = jax.random.split(key)
    rand = jax.random.randint(k1, (batch, seq_len + 1), 0, vocab)
    # markov structure: token_{i+1} == (token_i * 7 + 1) % vocab  w.p. ~0.75
    keep = jax.random.bernoulli(k2, 0.75, (batch, seq_len))

    def step_fn(tok, inp):
        k, r = inp
        nxt = jnp.where(k, (tok * 7 + 1) % vocab, r)
        return nxt, nxt

    _, rest = jax.lax.scan(step_fn, rand[:, 0],
                           (keep.T, rand[:, 1:].T))
    toks = jnp.concatenate([rand[:, :1], rest.T], axis=1)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


@dataclasses.dataclass
class TokenStream:
    seed: int
    batch: int
    seq_len: int
    vocab: int
    n_workers: int = 1

    def __call__(self, step: int) -> Dict[str, jax.Array]:
        """Batch with a leading worker axis (H-SGD layout)."""
        bs = [synth_lm_batch(self.seed, step, self.batch, self.seq_len,
                             self.vocab, worker=w) for w in range(self.n_workers)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *bs)
