"""Chameleon-34B [arXiv:2405.09818] — early-fusion VLM.

48 layers, d_model 8192, 64 heads GQA kv=8 (head_dim 128), d_ff 22016,
vocab 65536 (text + VQ image tokens share one vocabulary — early fusion means
images ARE tokens; the VQ-VAE image tokenizer is the stubbed frontend and
``input_specs`` feeds mixed token ids directly).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    source="arXiv:2405.09818",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    mlp_variant="swiglu",
    rope_theta=10_000.0,
    block_pattern=("global",),
)
