"""Gemma-3-12B [hf:google/gemma-3-1b-pt family card, scaled per assignment].

Dense: 48 layers, d_model 3840, 16 heads GQA kv=8 (head_dim 256), d_ff 15360,
vocab 262144. 5:1 local:global layer interleave, sliding window 1024 on local
layers, 128k context via the global layers. Attention logit softcapping and
RMSNorm per the Gemma family.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    sliding_window=1024,
    block_pattern=("local", "local", "local", "local", "local", "global"),
    mlp_variant="geglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
