"""OLMoE-1B-7B [arXiv:2409.02060].

MoE: 16 layers, d_model 2048, 16 heads (kv=16), expert d_ff 1024,
vocab 50304, 64 experts top-8, full attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    source="arXiv:2409.02060",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    num_experts=64,
    num_experts_per_tok=8,
    moe_d_ff=1024,
    mlp_variant="swiglu",
    rope_theta=10_000.0,
    block_pattern=("global",),
)
