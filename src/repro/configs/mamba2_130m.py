"""Mamba2-130M [arXiv:2405.21060] — SSD (state-space duality).

Attention-free SSM: 24 layers, d_model 768, d_inner 1536 (expand 2),
ssm_state 128, head_dim 64 (24 heads), vocab 50280, no FFN (d_ff=0).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=24,
    d_model=768,
    num_heads=24,          # d_inner // ssm_head_dim
    num_kv_heads=24,
    d_ff=0,
    vocab_size=50280,
    mlp_variant="none",
    block_pattern=("ssd",),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=64,
    tie_embeddings=True,
)
