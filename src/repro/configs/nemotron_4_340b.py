"""Nemotron-4-340B [arXiv:2402.16819 / 2406.11704].

Dense decoder-only: 96 layers, d_model 18432, 96 heads with GQA kv=8
(head_dim 192), d_ff 73728 with squared-ReLU MLP, vocab 256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    source="arXiv:2402.16819",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    mlp_variant="relu2",
    rope_theta=10_000.0,
    block_pattern=("global",),
    norm="layernorm",
)
