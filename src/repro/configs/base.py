"""Unified model configuration for every assigned architecture family.

One dataclass covers dense / moe / ssm / hybrid / audio (enc-dec) / vlm.
Fields irrelevant to a family keep their defaults; ``family`` selects the
forward-pass builder in ``repro.models.model``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = "unnamed"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    source: str = ""       # citation for the exact numbers

    # trunk
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: Optional[int] = None  # default d_model // num_heads

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None     # SWA width when a layer is 'local'
    # per-pattern-unit layer kinds, tiled over depth.  entries:
    #   'global' (full attn) | 'local' (SWA) | 'rglru' (RG-LRU block) | 'ssd' (Mamba-2)
    block_pattern: Tuple[str, ...] = ("global",)
    attn_logit_softcap: Optional[float] = None

    # mlp
    mlp_variant: str = "swiglu"  # swiglu | relu2 | geglu | gelu | none
    tie_embeddings: bool = False

    # moe
    num_experts: int = 0         # 0 => dense mlp
    num_experts_per_tok: int = 0
    moe_d_ff: Optional[int] = None  # expert hidden size (olmoe: 1024); default d_ff
    router_aux_loss_coef: float = 0.01
    capacity_factor: float = 1.25

    # ssm (mamba2 SSD)
    ssm_state: int = 0
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64

    # rglru (recurrentgemma)
    rglru_width: Optional[int] = None   # recurrence width; default d_model
    conv1d_width: int = 4

    # enc-dec (seamless)
    num_encoder_layers: int = 0
    encoder_frames_ratio: int = 4   # encoder length = seq_len // ratio (stub frontend)

    # norm / dtypes
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"         # activations/compute
    param_dtype: str = "bfloat16"   # stored params

    # runtime knobs (not architecture): set by launchers
    remat: bool = False
    use_pallas: bool = False        # route attention/ssd/rglru through Pallas kernels
    attn_chunk_q: int = 512         # q-block for the memory-bounded jnp path
    moe_group: int = 2048           # GShard token-group size
    # 'einsum' = classic GShard one-hot dispatch (O(T*E*C*d) flops/bytes);
    # 'gather' = index-based dispatch (O(E*C*d) bytes, no dispatch matmul) —
    # §Perf iteration, numerically identical (tested)
    moe_dispatch: str = "einsum"
    # optional activation sharding constraint on the residual stream
    # (PartitionSpec entries for (batch, seq, d_model)), applied inside the
    # layer scan; None entries = unconstrained.  Used by §Perf iterations.
    act_pspec: Optional[Tuple[Optional[str], ...]] = None

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.num_experts and self.moe_d_ff is None:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.rglru_width is None:
            object.__setattr__(self, "rglru_width", self.d_model)

    # ---- derived quantities -------------------------------------------------
    @property
    def num_pattern_units(self) -> int:
        """Full pattern repetitions (scanned); remainder layers are unrolled."""
        return self.num_layers // len(self.block_pattern)

    @property
    def pattern_remainder(self) -> Tuple[str, ...]:
        """Trailing layers when depth is not a multiple of the pattern
        (e.g. recurrentgemma-2b: 26 layers, unit (rglru, rglru, local))."""
        r = self.num_layers % len(self.block_pattern)
        return self.block_pattern[:r]

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        return self.block_pattern * self.num_pattern_units + self.pattern_remainder

    @property
    def d_head(self) -> int:
        return self.head_dim  # type: ignore[return-value]

    @property
    def is_subquadratic(self) -> bool:
        """True when the arch can serve ~500k context (SWA / SSM / RG-LRU)."""
        kinds = set(self.block_pattern)
        if kinds <= {"local", "rglru", "ssd"}:
            return True
        # mixed local/global (gemma3) still bounds *most* layers; we accept
        # patterns that contain any sub-quadratic kind AND use a sliding window
        # for their 'local' layers, following the task's carve-out.
        return ("local" in kinds or "ssd" in kinds or "rglru" in kinds)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS; exactness
        is tested against actual pytrees for the reduced variants)."""
        d, L = self.d_model, self.num_layers
        total = self.vocab_size * d          # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d     # lm head
        total += d                            # final norm
        for kind in self.layer_kinds:
            per = 0
            if kind in ("global", "local"):
                hq = self.num_heads * self.d_head
                hk = self.num_kv_heads * self.d_head
                per += d * hq + 2 * d * hk + hq * d          # q,k,v,o
                if self.qkv_bias:
                    per += hq + 2 * hk
                per += d                                      # pre-attn norm
            elif kind == "ssd":
                di = self.ssm_expand * d
                nh = di // self.ssm_head_dim
                conv_dim = di + 2 * self.ssm_state
                per += d * (2 * di + 2 * self.ssm_state + nh)  # in_proj
                per += conv_dim * self.ssm_conv_width          # conv
                per += 2 * nh                                  # A_log, D
                per += nh                                      # dt_bias
                per += di                                      # out norm
                per += di * d                                  # out_proj
                per += d                                       # pre norm
            elif kind == "rglru":
                w = self.rglru_width
                per += d * w * 2 + w * d                       # in_x, in_gate, out
                per += w * self.conv1d_width + w               # conv1d
                per += 2 * w * w + w                           # w_a, w_i, Lambda
                per += d                                       # pre norm
            # mlp part (attention blocks and Griffin recurrent blocks have MLPs)
            if kind in ("global", "local", "rglru"):
                if self.num_experts:
                    e_ff = self.moe_d_ff
                    n_mats = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
                    per += self.num_experts * n_mats * d * e_ff
                    per += d * self.num_experts                # router
                elif self.mlp_variant != "none":
                    n_mats = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
                    per += n_mats * d * self.d_ff
                per += d                                       # pre-mlp norm
            total += per
        if self.num_encoder_layers:
            # encoder layers: full attn + mlp, same widths
            hq = self.num_heads * self.d_head
            hk = self.num_kv_heads * self.d_head
            enc = d * hq + 2 * d * hk + hq * d + 2 * d
            n_mats = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
            enc += n_mats * d * self.d_ff
            # decoder cross-attention (one per decoder layer) accounted here
            cross = d * hq + 2 * d * hk + hq * d + d
            total += enc * self.num_encoder_layers + cross * L
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        n_mats = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
        per_expert = n_mats * d * self.moe_d_ff
        inactive = (self.num_experts - self.num_experts_per_tok) * per_expert
        return int(self.param_count() - inactive * self.num_layers)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (<=2 layers, d<=512,
    <=4 experts), preserving every structural trait of the full config."""
    pat = cfg.block_pattern
    if len(pat) > 3:  # compress e.g. gemma3's (local*5, global) -> (local, global)
        pat = tuple(dict.fromkeys(pat))
    d_model = min(cfg.d_model, 128)
    n_heads = min(cfg.num_heads, 4)
    n_kv = max(1, min(cfg.num_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    changes = dict(
        block_pattern=pat,
        num_layers=max(2, len(pat)),
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=n_kv,
        head_dim=d_model // n_heads,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else None,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2) if cfg.num_experts else 0,
        moe_d_ff=min(cfg.moe_d_ff, 128) if cfg.num_experts else None,
        # no-drop capacity in smoke variants so prefill/decode/forward agree
        capacity_factor=(min(cfg.num_experts, 4) / max(1, min(cfg.num_experts_per_tok, 2)))
        if cfg.num_experts else cfg.capacity_factor,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_chunk=8 if cfg.ssm_state else cfg.ssm_chunk,
        rglru_width=d_model,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        dtype="float32",
        param_dtype="float32",
        name=cfg.name + "-smoke",
    )
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
