"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427].

Hybrid: 26 layers, d_model 2560, 10 heads GQA kv=1 (head_dim 256), d_ff 7680.
Block pattern: (rglru, rglru, local-attention) — 1 attention per 2 RG-LRU
blocks; 26 layers = 8 full units + 2 trailing RG-LRU blocks. Local attention
window 2048.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    source="arXiv:2402.19427",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    sliding_window=2048,
    block_pattern=("rglru", "rglru", "local"),
    mlp_variant="geglu",
    rglru_width=2560,
    conv1d_width=4,
    tie_embeddings=True,
)
