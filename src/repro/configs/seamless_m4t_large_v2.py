"""SeamlessM4T-large-v2 [arXiv:2308.11596] — transformer backbone only.

Encoder-decoder: 24 encoder + 24 decoder layers, d_model 1024, 16 heads
(kv=16 — full MHA), d_ff 8192, vocab 256206. The modality frontend
(mel-spectrogram + conv feature extractor) is a STUB: ``input_specs`` feeds
precomputed frame embeddings of shape (batch, frames, d_model) to the encoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    source="arXiv:2308.11596",
    num_layers=24,
    num_encoder_layers=24,
    encoder_frames_ratio=4,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    mlp_variant="gelu",
    norm="layernorm",
    block_pattern=("global",),
)
