"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig, reduced

_ARCHS = {
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3_8b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
}

ARCH_IDS = tuple(_ARCHS)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCHS)}")
    return importlib.import_module(_ARCHS[arch]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS", "INPUT_SHAPES", "InputShape", "ModelConfig",
    "all_configs", "get_config", "reduced",
]
