"""Qwen2-0.5B [arXiv:2407.10671].

Dense: 24 layers, d_model 896, 14 heads GQA kv=2 (head_dim 64), d_ff 4864,
vocab 151936, QKV bias, SwiGLU, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    source="arXiv:2407.10671",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    mlp_variant="swiglu",
    rope_theta=1_000_000.0,
    block_pattern=("global",),
)
