"""Phi-3-mini-3.8B [arXiv:2404.14219].

Dense: 32 layers, d_model 3072, 32 heads kv=32 (head_dim 96), d_ff 8192,
vocab 32064. RoPE + SwiGLU.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    source="arXiv:2404.14219",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    mlp_variant="swiglu",
    rope_theta=10_000.0,
    block_pattern=("global",),
)
