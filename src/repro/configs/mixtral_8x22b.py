"""Mixtral-8x22B [arXiv:2401.04088].

MoE: 56 layers, d_model 6144, 48 heads GQA kv=8 (head_dim 128), expert
d_ff 16384, vocab 32768, 8 experts top-2, sliding-window attention (4096).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    source="arXiv:2401.04088",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    sliding_window=4096,
    block_pattern=("local",),
    num_experts=8,
    num_experts_per_tok=2,
    mlp_variant="swiglu",
    rope_theta=1_000_000.0,
)
