"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

MUST set the host-device override before ANY other import (jax locks the
device count at first init).
"""
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config  # noqa: E402
from repro.configs.base import InputShape, ModelConfig  # noqa: E402
from repro.core import HSGD, HierarchySpec, SyncEvent, make_topology  # noqa: E402
from repro.models import build_model, decode_state_specs, train_batch_specs  # noqa: E402
from repro.models.frontends import audio_frame_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh, n_replicas  # noqa: E402
from repro.launch.partitioning import (batch_shardings, cache_shardings,  # noqa: E402
                                       params_shardings, replicated)
from repro.optim import sgd  # noqa: E402
from repro.roofline import analyze_compiled, combine_train_steps  # noqa: E402

# H-SGD periods used for the production roofline (representative of the
# paper's CIFAR sweet spot G=50, I=5 scaled to round powers of two)
HSGD_G, HSGD_I = 64, 8

# long_500k only for sub-quadratic archs (see DESIGN.md shape-skip table)
LONG_OK = {"gemma3-12b", "recurrentgemma-2b", "mamba2-130m", "mixtral-8x22b"}


def applicable(arch: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch in LONG_OK
    return True


def _worker_batch_specs(cfg: ModelConfig, shape: InputShape, n: int) -> Dict:
    """Global batch -> (n_workers, per_worker, ...) ShapeDtypeStructs."""
    g = train_batch_specs(cfg, shape)
    assert shape.global_batch % n == 0, (shape.global_batch, n)

    def reshape(s):
        return jax.ShapeDtypeStruct((n, s.shape[0] // n) + s.shape[1:], s.dtype)

    return jax.tree.map(reshape, g)


def _state_specs(model, opt, n: int):
    p0 = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    o0 = jax.eval_shape(opt.init, p0)
    lead = lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype)
    return (jax.tree.map(lead, p0), jax.tree.map(lead, o0))


REPLICA_HBM_BUDGET = 8e9  # bytes/chip for one worker's param shard


def train_plan(cfg: ModelConfig, mesh) -> Dict:
    """Choose the H-SGD worker<->mesh mapping by replica memory.

    'replica' (default): every (pod, data) index is a worker — n=32 full
      replicas (multi-pod), params sharded only on 'model' within a worker.
    'fsdp': for archs whose replica does not fit HBM at n=replica density
      (nemotron-340b, mixtral-8x22b): workers = pods only (n=2), the 'data'
      axis becomes intra-worker batch parallelism + FSDP param sharding.
      Single-pod fsdp degenerates to n=1 (H-SGD needs >=2 pods at this
      scale — recorded in DESIGN.md).
    """
    n_chips = int(np.prod(list(mesh.shape.values())))
    multi = "pod" in mesh.axis_names
    n_dense = (mesh.shape["pod"] * mesh.shape["data"]) if multi \
        else mesh.shape["data"]
    bytes_per_param = 2 if cfg.param_dtype == "bfloat16" else 4
    per_chip_dense = cfg.param_count() * bytes_per_param * n_dense / n_chips
    if per_chip_dense <= REPLICA_HBM_BUDGET:
        if multi:
            spec = HierarchySpec((mesh.shape["pod"], mesh.shape["data"]),
                                 (HSGD_G, HSGD_I))
            lead = ("pod", "data")
        else:
            d = mesh.shape["data"]
            spec = HierarchySpec((4, d // 4), (HSGD_G, HSGD_I))
            lead = ("data",)
        return {"mapping": "replica", "spec": spec, "lead": lead,
                "fsdp_axis": None, "data_axis": None}
    if multi:
        spec = HierarchySpec((mesh.shape["pod"],), (HSGD_G,))
        lead = ("pod",)
    else:
        spec = HierarchySpec((1,), (HSGD_G,))
        lead = ()
    return {"mapping": "fsdp", "spec": spec, "lead": lead,
            "fsdp_axis": "data", "data_axis": "data"}


# ---------------------------------------------------------------------------
# lowerings per shape kind
# ---------------------------------------------------------------------------
def lower_train(cfg: ModelConfig, shape: InputShape, mesh,
                kinds=("local", "local_sync", "global_sync"), *,
                sync_dtype: str = "float32",
                model_shard: bool = True,
                seq_axis: Optional[str] = None,
                accum_steps: int = 1,
                levels: int = 2):
    """sync_dtype / model_shard / seq_axis / accum_steps are §Perf hillclimb
    knobs: bf16 aggregation payloads, DP-only parameter layout (replicate
    weights within a worker), sequence sharding of the batch over an axis,
    and microbatch gradient accumulation.  levels=3 lowers a THREE-level
    hierarchy (Algorithm D.1) on the multi-pod mesh: pods / data-quadrants /
    workers with nested periods (G, G/4, I)."""
    model = build_model(cfg)
    opt = sgd(1e-3)
    plan = train_plan(cfg, mesh)
    if levels == 3:
        assert plan["mapping"] == "replica" and "pod" in mesh.axis_names, \
            "3-level demo needs the replica mapping on the multi-pod mesh"
        d = mesh.shape["data"]
        plan["spec"] = HierarchySpec(
            (mesh.shape["pod"], 4, d // 4), (HSGD_G, HSGD_G // 4, HSGD_I))
    spec: HierarchySpec = plan["spec"]
    n = spec.n_workers
    topo = make_topology("uniform", spec=spec, sync_dtype=sync_dtype)
    eng = HSGD(model.loss, opt, topo, jit=False, accum_steps=accum_steps)

    p_spec, o_spec = _state_specs(model, opt, n)
    from repro.core.hsgd import HSGDState
    state_spec = HSGDState(p_spec, o_spec, jax.ShapeDtypeStruct((), jnp.int32))
    batch_spec = _worker_batch_specs(cfg, shape, n)

    lead = plan["lead"]
    state_sh = HSGDState(
        params=params_shardings(mesh, p_spec, lead_worker=lead,
                                fsdp_axis=plan["fsdp_axis"],
                                model_shard=model_shard),
        opt_state=params_shardings(mesh, o_spec, lead_worker=lead,
                                   fsdp_axis=plan["fsdp_axis"],
                                   model_shard=model_shard),
        step=NamedSharding(mesh, P()))
    batch_sh = batch_shardings(mesh, batch_spec, lead_worker=lead,
                               data_axis=plan["data_axis"])
    if seq_axis is not None:
        def reshard(sh):
            entries = list(sh.spec) + [None] * 3
            entries[2] = seq_axis
            return NamedSharding(mesh, P(*entries[:3]))
        batch_sh = jax.tree.map(reshard, batch_sh)

    # M=1 hierarchies (fsdp mapping) have no distinct local sync
    kind_map = {"local": None, "global_sync": SyncEvent(level=1)}
    if spec.num_levels >= 2:
        kind_map["local_sync"] = SyncEvent(level=spec.num_levels)
    if spec.num_levels >= 3:
        kind_map["mid_sync"] = SyncEvent(level=2)
    out = {}
    for kname in kinds:
        if kname not in kind_map:
            continue
        step = eng.step_fn(kind_map[kname])
        metrics_sh = None  # let GSPMD place scalars
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, metrics_sh))
        lowered = fn.lower(state_spec, batch_spec)
        out[kname] = lowered
    out["_plan"] = plan
    return out


def lower_prefill(cfg: ModelConfig, shape: InputShape, mesh):
    model = build_model(cfg)
    tok_spec = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                    jnp.int32)
    p0 = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = params_shardings(mesh, p0, fsdp_axis="data")
    tok_sh = batch_shardings(mesh, tok_spec)
    kwargs = {}
    if cfg.family == "encdec":
        enc = audio_frame_specs(cfg, shape)
        kwargs["enc_inputs"] = enc
        enc_sh = batch_shardings(mesh, enc)
        fn = jax.jit(
            lambda p, t, e: model.prefill(p, t, max_len=shape.seq_len,
                                          enc_inputs=e),
            in_shardings=(p_sh, tok_sh, enc_sh))
        return {"prefill": fn.lower(p0, tok_spec, enc)}
    fn = jax.jit(lambda p, t: model.prefill(p, t, max_len=shape.seq_len),
                 in_shardings=(p_sh, tok_sh))
    return {"prefill": fn.lower(p0, tok_spec)}


def lower_decode(cfg: ModelConfig, shape: InputShape, mesh):
    model = build_model(cfg)
    specs = decode_state_specs(cfg, shape)
    cache_spec, tok_spec = specs["cache"], specs["token"]
    p0 = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = params_shardings(mesh, p0, fsdp_axis="data")
    c_sh = cache_shardings(mesh, cache_spec, shape.global_batch)
    n_rep = n_replicas(mesh)
    rep = tuple(a for a in mesh.axis_names if a != "model")
    tok_sh = NamedSharding(
        mesh, P(rep if len(rep) > 1 else rep[0])
        if shape.global_batch % n_rep == 0 else P())
    fn = jax.jit(model.decode_step,
                 in_shardings=(p_sh, c_sh, tok_sh),
                 out_shardings=(None, c_sh))
    return {"decode": fn.lower(p0, cache_spec, tok_spec)}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def run_pair(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if shape.kind == "train":
        lowered = lower_train(cfg, shape, mesh)
    elif shape.kind == "prefill":
        lowered = lower_prefill(cfg, shape, mesh)
    else:
        lowered = lower_decode(cfg, shape, mesh)
    t_lower = time.time() - t0
    plan = lowered.pop("_plan", None)

    reports, mems = {}, {}
    for kname, low in lowered.items():
        t1 = time.time()
        compiled = low.compile()
        rep = analyze_compiled(f"{arch}/{shape_name}/{kname}", compiled,
                               pod_size=256)
        reports[kname] = rep
        mems[kname] = rep.peak_memory_bytes
        if verbose:
            print(f"  [{kname}] compile {time.time()-t1:.1f}s  "
                  f"flops/chip {rep.flops_per_chip:.3e}  "
                  f"bytes/chip {rep.bytes_per_chip:.3e}  "
                  f"coll intra {rep.coll_intra:.3e} cross {rep.coll_cross:.3e}  "
                  f"peakmem {0 if rep.peak_memory_bytes is None else rep.peak_memory_bytes:.3e}")
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "lower_s": t_lower,
        "mapping": None if plan is None else plan["mapping"],
        "n_workers": None if plan is None else plan["spec"].n_workers,
        "steps": {k: r.asdict() for k, r in reports.items()},
    }
    if shape.kind == "train":
        rec["amortized"] = combine_train_steps(reports, HSGD_G, HSGD_I)
    # headline report: global_sync for train (worst step), else the only step
    head = reports.get("global_sync") or next(iter(reports.values()))
    rec["dominant"] = head.dominant
    rec["terms_s"] = {"compute": head.compute_s, "memory": head.memory_s,
                      "collective": head.collective_s}
    # useful-compute ratio
    model_flops = model_flops_per_step(cfg, shape)
    n_chips = int(np.prod(list(mesh.shape.values())))
    hlo = head.flops_per_chip * (1.0 if shape.kind != "train" else 1.0)
    rec["model_flops_per_chip"] = model_flops / n_chips
    rec["useful_ratio"] = (model_flops / n_chips) / max(head.flops_per_chip, 1)
    return rec


def model_flops_per_step(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS: 6*N*D for train (fwd+bwd), 2*N*D per generated/processed
    token at inference. MoE: active params only."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    results = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    failures = []
    for arch in archs:
        for shape in shapes:
            if not applicable(arch, shape):
                continue
            for mp in meshes:
                key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
                if key in results and not args.force:
                    print(f"skip (cached): {key}")
                    continue
                print(f"=== {key}")
                try:
                    rec = run_pair(arch, shape, mp)
                    results[key] = rec
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((key, str(e)))
    print(f"\ndone: {len(results)} cached results, {len(failures)} failures")
    for k, e in failures:
        print(" FAIL", k, e[:200])
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
