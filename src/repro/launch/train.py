"""End-to-end H-SGD training driver.

Runs on whatever devices exist (CPU smoke -> TPU pods): builds the model from
--arch (reduced variant on CPU), an H-SGD topology (--workers/--groups/--G/--I,
optionally --levels for multi-level), the synthetic token pipeline, and trains
with periodic checkpointing + divergence telemetry.

Execution goes through the schedule-compiled round executor (``run_rounds``):
each pure-local block is one fused dispatch, with the schedule additionally
cut at the telemetry cadence so checkpoints/divergences land exactly on their
steps.  ``--backend`` picks the executor: ``sim`` (default; vmap over the
worker axis on one device) or ``mesh`` (shard_map over a hierarchy-shaped
device mesh — needs prod(level sizes) devices; sync events lower to
named-axis all-reduces).

``--runtime`` prices the schedule in simulated seconds (straggler clocks,
per-level links, optional ``--deadline`` elastic participation —
repro.runtime); telemetry then carries sim_time_s / sim_sync_s and the run
ends with a runtime breakdown + planner constants fitted from the trace.

``--probes`` turns on the in-graph observability layer (repro.obs): the
per-level parameter divergences are measured ON device at every sync event
and drained in bulk — no host gradient recompute, no schedule cut — and
``--trace out.json`` exports the run as Perfetto/Chrome-trace JSON.

``--population`` switches to the sampled-participation regime
(repro.population): the topology's n workers become the k *active slots* of
a declared virtual-client population (cells per level, ``C1xC2x...``), each
sampling round (one global period G) draws fresh clients hierarchically,
and results fold back into a server model — so ``--steps`` must be a
multiple of G and telemetry becomes one record per round.

Flags are grouped per subsystem (``--help`` shows the groups); every
subsystem group builds one section of the engine's ``EngineConfig``, which
is echoed verbatim as the run's JSONL header line.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --workers 8 --groups 2 --G 8 --I 2 --steps 60 --batch 4 --seq 64 \
      --runtime 0.004,0.005:1e9,0.0003:1e10 --straggler lognormal:0.8 \
      --deadline 0.004
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --workers 8 --groups 2 --G 8 --I 2 --steps 64 --batch 4 --seq 64 \
      --population 1000x1000 --sample-k 8 --sample-seed 7
"""
from __future__ import annotations

import argparse
import json
import math
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import restore, save
from repro.comms import Comms
from repro.configs import get_config, reduced
from repro.core import (EngineConfig, HSGD, HierarchySpec, all_divergences,
                        contiguous, make_topology, per_worker_grads)
from repro.data import TokenStream
from repro.models import build_model
from repro.optim import cosine, momentum, sgd


def build_argparser():
    """Flags grouped per subsystem; each subsystem group feeds one section
    of the engine's :class:`~repro.core.EngineConfig` (echoed as the JSONL
    header's ``config`` line)."""
    ap = argparse.ArgumentParser(
        description="H-SGD training driver (repro.launch.train)")

    g = ap.add_argument_group("model")
    g.add_argument("--arch", default="qwen2-0.5b")
    g.add_argument("--reduced", action="store_true",
                   help="CPU-scale same-family variant")

    g = ap.add_argument_group(
        "topology", "hierarchy shape + the aggregation rule at sync events")
    g.add_argument("--workers", type=int, default=8)
    g.add_argument("--groups", type=int, default=2)
    g.add_argument("--G", type=int, default=8)
    g.add_argument("--I", type=int, default=2)
    g.add_argument("--levels", type=str, default="",
                   help="multi-level spec 'N1,N2,..:P1,P2,..' (overrides "
                        "--workers/--groups/--G/--I)")
    g.add_argument("--aggregator", default="mean",
                   choices=["mean", "compressed", "sign"],
                   help="aggregation rule applied at every sync event")
    g.add_argument("--sync-dtype", default=None,
                   help="aggregation payload dtype override (bfloat16 "
                        "halves sync bytes; alone it implies --aggregator "
                        "compressed)")

    g = ap.add_argument_group(
        "training", "optimizer, schedule length, data shape, executor")
    g.add_argument("--backend", default="sim", choices=["sim", "mesh"],
                   help="executor (EngineConfig.executor): 'sim' "
                        "(single-device vmap) or 'mesh' (shard_map; one "
                        "device per worker, sync events lower to "
                        "named-axis all-reduces)")
    g.add_argument("--steps", type=int, default=50)
    g.add_argument("--batch", type=int, default=4, help="per-worker batch")
    g.add_argument("--seq", type=int, default=64)
    g.add_argument("--lr", type=float, default=3e-3)
    g.add_argument("--optimizer", default="sgd", choices=["sgd", "momentum"])
    g.add_argument("--seed", type=int, default=0)

    g = ap.add_argument_group(
        "comms", "communication plan (EngineConfig.comms)")
    g.add_argument("--comms", default=None,
                   choices=["identity", "int8", "sign", "topk"],
                   help="fuse syncs into flat per-dtype buffers and ship "
                        "them through this codec (repro.comms); adds "
                        "per-level wire accounting to the telemetry.  "
                        "Default: off (bitwise-identical leaf-wise path)")
    g.add_argument("--comms-block", type=int, default=0,
                   help="codec block size override (int8/sign)")
    g.add_argument("--comms-rate", type=float, default=0.0,
                   help="top-k sparsification rate override (topk)")

    g = ap.add_argument_group(
        "runtime", "simulated-time heterogeneity (EngineConfig.runtime)")
    g.add_argument("--runtime", default=None,
                   help="simulated-time model 'COMPUTE[,LAT:BW,...]': "
                        "seconds per local step, then one latency:bandwidth"
                        " pair per hierarchy level outermost-first "
                        "(default links: a 10x-per-tier datacenter ladder)."
                        "  Adds sim_time_s / per-level sim_sync_s to the "
                        "telemetry and a final runtime report; sync cost "
                        "is priced from the comms payload bytes, so "
                        "--comms codecs visibly buy simulated time.  "
                        "Example: --runtime 0.004,0.005:1e9,0.0003:1e10")
    g.add_argument("--straggler", default=None,
                   help="heterogeneity regime 'name[:params]': "
                        "fixed[:frac:factor] | lognormal[:sigma] | "
                        "bursty[:p_enter:p_exit:factor] (needs --runtime)")
    g.add_argument("--deadline", default=None,
                   help="deadline-elastic participation: slack seconds "
                        "('2.0') or per-level 'L1:2.0,L2:0.5' — workers "
                        "missing a sync's deadline are dropped from that "
                        "event only, keeping their params and comms "
                        "residuals (needs --runtime; works on both "
                        "backends)")
    g.add_argument("--runtime-seed", type=int, default=0,
                   help="straggler sampler seed (draws are pure in "
                        "(seed, step): policies compare on identical "
                        "compute times)")

    g = ap.add_argument_group(
        "population",
        "sampled participation from a virtual-client population "
        "(EngineConfig.population; repro.population)")
    g.add_argument("--population", default="",
                   help="declare a virtual-client population as per-level "
                        "cell fanouts 'C1xC2x...' (e.g. 1000x1000 = 10^6 "
                        "clients behind a two-level topology); each "
                        "sampling round (one global period G) draws the "
                        "topology's n clients hierarchically and folds the "
                        "round back into a server model, so --steps must "
                        "be a multiple of G")
    g.add_argument("--sample-k", type=int, default=0,
                   help="expected active clients per round; cross-checked "
                        "against the topology's n (the draw always fills "
                        "exactly n slots)")
    g.add_argument("--sample-seed", type=int, default=0,
                   help="population sampler namespace: draws are pure in "
                        "(sample-seed, round)")

    g = ap.add_argument_group(
        "observability",
        "telemetry, probes, tracing, audits (EngineConfig.metrics)")
    g.add_argument("--audit", action="store_true",
                   help="print the repro.analysis collective audit of the "
                        "lowered sync plan (per-event sync ops, wire "
                        "dtypes, payload bytes, lint findings) before "
                        "training starts")
    g.add_argument("--probes", action="store_true",
                   help="in-graph observability (repro.obs): carry the "
                        "on-device divergence probe through training — "
                        "per-level parameter divergences at every sync "
                        "event (div_global/div_up_Lℓ/div_down_Lℓ in the "
                        "JSONL) plus a per-step grad_norm channel, drained "
                        "in one transfer at telemetry boundaries.  "
                        "--divergence-every is then satisfied by the "
                        "probe values (no host gradient recompute, no "
                        "schedule cut)")
    g.add_argument("--trace", default="",
                   help="export the run as Chrome-trace-event/Perfetto "
                        "JSON to this path (open in ui.perfetto.dev): "
                        "per-worker compute/wait spans and per-level sync "
                        "spans with --runtime, step-index spans without; "
                        "probe divergences ride along as counter tracks "
                        "with --probes")
    g.add_argument("--log-every", type=int, default=10)
    g.add_argument("--divergence-every", type=int, default=0)

    g = ap.add_argument_group("io", "checkpointing and output")
    g.add_argument("--ckpt-dir", default="")
    g.add_argument("--ckpt-every", type=int, default=0)
    g.add_argument("--out", default="")
    return ap


def make_runtime_model(args, num_levels: int):
    """--runtime 'COMPUTE[,LAT:BW,...]' (+ --straggler/--deadline/
    --runtime-seed) -> RuntimeModel, or None with the flag unset."""
    if not args.runtime:
        return None
    from repro.runtime import LinkModel, RuntimeModel
    parts = [p for p in args.runtime.split(",") if p]
    links = None
    if len(parts) > 1:
        if len(parts) - 1 != num_levels:
            raise SystemExit(
                f"--runtime: got {len(parts) - 1} LAT:BW pairs for a "
                f"{num_levels}-level hierarchy (need one per level, "
                f"outermost first)")
        links = tuple(LinkModel(float(lat), float(bw))
                      for lat, bw in (p.split(":") for p in parts[1:]))
    return RuntimeModel(compute_s=float(parts[0]), links=links,
                        straggler=args.straggler, policy=args.deadline,
                        seed=args.runtime_seed)


def make_spec(args) -> HierarchySpec:
    if args.levels:
        sizes, periods = args.levels.split(":")
        return HierarchySpec(tuple(int(x) for x in sizes.split(",")),
                             tuple(int(x) for x in periods.split(",")))
    assert args.workers % args.groups == 0
    return HierarchySpec((args.groups, args.workers // args.groups),
                         (args.G, args.I))


def _run_sampled(args, ap, eng, model, cfg, spec):
    """Population-mode training loop: one sampling round per global period,
    virtual clients' token streams keyed by client id (pure in
    ``(seed, client_id, t)``; empty slots get the reserved stream 0)."""
    from repro.data.synthetic import synth_lm_batch
    G = spec.periods[0]
    server = eng.init_server(jax.random.PRNGKey(args.seed), model.init)
    if args.audit:
        popeng = eng.population_engine()
        print(popeng.audit(server,
                           config=f"{args.backend}/{args.arch}/pop").summary())

    def batch_fn(client_ids, t):
        bs = [synth_lm_batch(args.seed, t, args.batch, args.seq,
                             cfg.vocab_size, worker=int(c) + 1)
              for c in client_ids]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *bs)

    t0 = time.time()
    server, hist = eng.run_sampled(server, batch_fn, args.steps // G)
    elapsed = round(time.time() - t0, 2)
    log_rounds = max(1, args.log_every // G)
    history = []
    for rec in hist:
        if rec["round"] % log_rounds and rec["t"] != args.steps:
            continue
        out = {"step": rec["t"], "round": rec["round"], "loss": rec["ce"],
               "elapsed_s": elapsed, "participation": rec["participation"]}
        for key in ("sim_time_s", "dropped", "wire_bytes"):
            if key in rec:
                out[key] = rec[key]
        history.append(out)
        print(json.dumps(out))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(history, f, indent=1)
    return history


def main(argv=None):
    ap = build_argparser()
    args = ap.parse_args(argv)
    # fail loudly on codec knobs that would otherwise be silently ignored
    if args.comms_block and args.comms not in ("int8", "sign"):
        ap.error(f"--comms-block only applies to --comms int8|sign "
                 f"(got --comms {args.comms})")
    if args.comms_rate and args.comms != "topk":
        ap.error(f"--comms-rate only applies to --comms topk "
                 f"(got --comms {args.comms})")
    if (args.straggler or args.deadline) and not args.runtime:
        ap.error("--straggler/--deadline need --runtime (the simulated "
                 "clock they perturb)")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    spec = make_spec(args)
    n = spec.n_workers

    population = None
    if args.population:
        from repro.population import Population
        try:
            cells = tuple(int(c) for c in
                          args.population.lower().replace("x", ",").split(",")
                          if c)
        except ValueError:
            ap.error(f"--population must be per-level cell fanouts like "
                     f"1000x1000 (got {args.population!r})")
        if len(cells) != spec.num_levels:
            ap.error(f"--population {args.population}: {len(cells)} cell "
                     f"fanouts for a {spec.num_levels}-level hierarchy "
                     f"(need one per level)")
        if args.sample_k and args.sample_k != n:
            ap.error(f"--sample-k {args.sample_k} != topology n={n}: the "
                     f"draw fills exactly one client per engine slot, so k "
                     f"is the topology's n (adjust --workers/--levels)")
        if args.steps % spec.periods[0] != 0:
            ap.error(f"--population: --steps {args.steps} must be a "
                     f"multiple of the global period G={spec.periods[0]} "
                     f"(one sampling round per global period)")
        for val, name in ((args.ckpt_dir, "--ckpt-dir"),
                          (args.trace, "--trace"),
                          (args.divergence_every, "--divergence-every")):
            if val:
                ap.error(f"{name} is not supported in population mode")
        population = Population(cells, seed=args.sample_seed)
    elif args.sample_k or args.sample_seed:
        ap.error("--sample-k/--sample-seed need --population")

    lr = cosine(args.lr, args.steps, warmup_steps=min(10, args.steps // 10))
    opt = sgd(lr) if args.optimizer == "sgd" else momentum(lr)
    topo = make_topology(
        "uniform", spec=spec, sync_dtype=args.sync_dtype,
        aggregator=None if args.aggregator == "mean" else args.aggregator)
    comms = None
    if args.comms:
        kw = {}
        if args.comms_block:
            kw["block"] = args.comms_block
        if args.comms_rate:
            kw["rate"] = args.comms_rate
        comms = Comms(args.comms, **kw)
    runtime = make_runtime_model(args, spec.num_levels)
    engine_config = EngineConfig(executor=args.backend, comms=comms,
                                 runtime=runtime,
                                 metrics="on" if args.probes else None,
                                 population=population)
    eng = HSGD(model.loss, opt, topo, engine_config)
    from repro.obs import SCHEMA_VERSION
    # JSONL header: the full engine configuration, round-trippable
    print(json.dumps({"schema_version": SCHEMA_VERSION,
                      "backend": args.backend, "probes": args.probes,
                      "config": engine_config.describe()}))

    if population is not None:
        return _run_sampled(args, ap, eng, model, cfg, spec)

    state = eng.init(jax.random.PRNGKey(args.seed), model.init)
    if args.audit:
        # sync-subprogram audit only (no batch_fn): fast, and enough for
        # the per-event sync-op/dtype/byte summary + R1/R2/R5 lints
        print(eng.audit(state, config=f"{args.backend}/{args.arch}").summary())
    if comms is not None:
        # static per-level wire accounting: what each sync event moves
        print(json.dumps({"wire": eng.wire_stats(state).summary(args.steps)}))

    stream = TokenStream(seed=args.seed, batch=args.batch, seq_len=args.seq,
                         vocab=cfg.vocab_size, n_workers=n)

    start = 0
    if args.ckpt_dir:
        try:
            start, tree = restore(args.ckpt_dir, {
                "params": state.params, "opt": state.opt_state})
            # codec residuals are not checkpointed: resume restarts error
            # feedback from the fresh (zero) state
            state = eng.executor.place(state.__class__(
                tree["params"], tree["opt"], jnp.asarray(start, jnp.int32),
                state.comms, state.metrics))
            print(f"resumed from step {start}")
        except AssertionError:
            pass

    # telemetry cadence: the round schedule is cut at the gcd of the
    # intervals that need exact-step STATE (checkpoints, divergences), so
    # those land on round boundaries.  Logging reads the per-step history
    # and needs no cut — including it here would degenerate coprime
    # cadences to gcd 1, i.e. per-step dispatch.
    ckpt_every = args.ckpt_every if args.ckpt_dir else 0
    # with --probes the in-graph probe supplies divergences at every sync
    # step (drained in one bulk transfer), so --divergence-every needs
    # neither the host gradient recompute nor a schedule cut
    div_every = 0 if args.probes else args.divergence_every
    intervals = [v for v in (div_every, ckpt_every) if v]
    eval_every = math.gcd(*intervals) if intervals else 0
    # per-level divergence groupings come from the topology (a >2-level
    # schedule reports every internal level, not just level 1)
    groupings = topo.level_groupings() or {1: contiguous(n, 1)}
    t0 = time.time()

    def telemetry(st, t):
        step = t + 1
        rec = {"elapsed_s": round(time.time() - t0, 2)}
        if div_every and step % div_every == 0:
            g = per_worker_grads(model.loss, eng.mean_params(st),
                                 stream(10_000_000 + t))
            rec["divergence"] = {f"L{lvl}": all_divergences(g, gr)
                                 for lvl, gr in groupings.items()}
        if ckpt_every and step % ckpt_every == 0:
            save(args.ckpt_dir, step,
                 {"params": st.params, "opt": st.opt_state})
        return rec

    recorder = None
    if args.trace:
        from repro.obs import TraceRecorder
        recorder = TraceRecorder()
    state, step_hist = eng.run_rounds(
        state, stream, args.steps - start,
        eval_every=eval_every, eval_fn=telemetry, trace=recorder)

    # un-hooked steps get the elapsed_s of the NEXT measured boundary (the
    # telemetry point whose rounds covered them): an upper bound, and
    # monotonic — a plain end-of-run fallback would make earlier records
    # report larger elapsed than later ones
    nxt = round(time.time() - t0, 2)
    for srec in reversed(step_hist):
        nxt = srec.setdefault("elapsed_s", nxt)
    history = []
    wire_cum = 0
    if args.probes:
        from repro.obs import validate_record
    for srec in step_hist:
        step = srec["t"]
        wire_cum += srec.get("wire_bytes", 0)
        # record log-cadence steps, the final step, and every step that
        # carries divergence telemetry — host oracle or in-graph probe
        # (their cadences may not align with --log-every)
        if step % args.log_every == 0 or step == args.steps \
                or "divergence" in srec or "div_global" in srec:
            rec = {"step": step,
                   "loss": srec["ce"],
                   "lvl": spec.sync_level(step - 1),
                   "elapsed_s": srec["elapsed_s"]}
            if "grad_norm" in srec:
                rec["grad_norm"] = srec["grad_norm"]
            if comms is not None:
                rec["wire_cum_bytes"] = wire_cum
            if "sim_time_s" in srec:
                rec["sim_time_s"] = srec["sim_time_s"]
                rec["sim_sync_s"] = srec["sim_sync_s"]
            if "dropped" in srec:
                rec["dropped"] = srec["dropped"]
            rec.update({k: v for k, v in srec.items()
                        if k.startswith("div_")})
            if "divergence" in srec:
                rec["divergence"] = srec["divergence"]
            if args.probes:
                # the launcher's record is fully registered on the metrics
                # bus: lint strictly (None lvl = between syncs, skipped)
                errs = validate_record(
                    {k: v for k, v in rec.items() if v is not None},
                    strict=True)
                if errs:
                    raise SystemExit("metrics-bus violations: "
                                     + "; ".join(errs))
            history.append(rec)
            print(json.dumps(rec))
    if recorder is not None:
        from repro.obs import validate_trace
        assert not validate_trace(recorder), validate_trace(recorder)
        recorder.save(args.trace)
        print(json.dumps({"trace": args.trace,
                          "trace_events": len(recorder.events)}))
    if runtime is not None:
        # where the simulated time went (makespan, waits, per-level links,
        # drop counts) + the fitted planner constants, closing the loop
        # simulate -> fit -> enumerate_plans
        from repro.core import CommModel
        fit = CommModel.fit_from_trace(step_hist, topo)
        print(json.dumps({"runtime": eng.runtime_report(),
                          "fitted_comm_model": {
                              "compute_s": round(fit.compute_s, 9),
                              "local_round_s": round(fit.local_round_s, 9),
                              "global_round_s": round(fit.global_round_s, 9),
                          }}))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(history, f, indent=1)
    return history


if __name__ == "__main__":
    main()
