"""Production meshes.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16) — the 'pod' axis
carries H-SGD's global aggregation (slow DCI), 'data' the local aggregations
(fast ICI), 'model' tensor parallelism inside a worker.

Functions, not module constants: importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over host devices for CPU integration tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def replica_axes(mesh) -> tuple:
    """Mesh axes carrying H-SGD worker replicas (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_replicas(mesh) -> int:
    out = 1
    for a in replica_axes(mesh):
        out *= mesh.shape[a]
    return out
