"""Production meshes.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16) — the 'pod' axis
carries H-SGD's global aggregation (slow DCI), 'data' the local aggregations
(fast ICI), 'model' tensor parallelism inside a worker.

``make_hsgd_mesh`` generalizes this to any uniform hierarchy: one replica
mesh axis per level (outermost = level 1, the slow/global fabric), so the
mesh executor's level-ℓ sync is an all-reduce over exactly the axes of
levels >= ℓ.

Functions, not module constants: importing this module never touches jax
device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

# Replica-axis naming per hierarchy depth; level 1 (global, slow fabric)
# first.  Deeper-than-3 hierarchies fall back to generic lvl<ℓ> names.
_LEVEL_AXIS_NAMES = {1: ("data",), 2: ("pod", "data"),
                     3: ("pod", "rack", "data")}


def level_axis_names(num_levels: int) -> Tuple[str, ...]:
    """Replica mesh axis names for a ``num_levels``-deep hierarchy."""
    return _LEVEL_AXIS_NAMES.get(
        num_levels, tuple(f"lvl{l}" for l in range(1, num_levels + 1)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_hsgd_mesh(group_sizes: Tuple[int, ...], n_model: int = 1,
                   axis_names: Optional[Tuple[str, ...]] = None):
    """Mesh whose replica axes mirror a uniform hierarchy: axis ℓ has size
    N_ℓ (``group_sizes``, outermost first), plus a trailing 'model' axis for
    within-worker tensor parallelism.  Needs prod(group_sizes) * n_model
    devices.  For a ``GroupedTopology`` (no per-level axis structure) pass
    ``(n_workers,)`` — grouped events lower over the flat worker axis with
    one-hot membership weights, so any replica factorization whose product
    is ``n_workers`` also works."""
    names = tuple(axis_names) if axis_names else level_axis_names(
        len(group_sizes))
    assert len(names) == len(group_sizes), (names, group_sizes)
    return jax.make_mesh(tuple(group_sizes) + (n_model,), names + ("model",))


def make_host_mesh(n_data: int = 1, n_model: int = 1, *,
                   group_sizes: Optional[Tuple[int, ...]] = None):
    """Tiny mesh over host devices for CPU integration tests.  With
    ``group_sizes``, builds the hierarchy-shaped mesh of ``make_hsgd_mesh``
    (one replica axis per level) instead of the flat ('data','model') one."""
    if group_sizes is not None:
        return make_hsgd_mesh(tuple(group_sizes), n_model=n_model)
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def replica_axes(mesh) -> tuple:
    """Mesh axes carrying H-SGD worker replicas (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_replicas(mesh) -> int:
    out = 1
    for a in replica_axes(mesh):
        out *= mesh.shape[a]
    return out
