"""Sharding rules: pytree -> PartitionSpec pytree.

Tensor parallelism ('model' axis): for each >=2-D leaf, shard the largest dim
divisible by the model-axis size (ties -> last dim).  1-D leaves (biases,
norm scales, A_log, ...) are replicated.  H-SGD training state additionally
carries a leading worker axis sharded over the replica axes (('pod','data')
multi-pod, ('data',) single-pod).  Decode caches shard batch over the replica
axes when divisible, else the cache *sequence* dim (long_500k batch=1).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _model_dim(shape: Tuple[int, ...], model_size: int,
               skip_axes: int = 0) -> Optional[int]:
    best, best_size = None, 0
    for i in range(skip_axes, len(shape)):
        if shape[i] % model_size == 0 and shape[i] >= best_size:
            best, best_size = i, shape[i]
    return best


def param_spec(shape: Tuple[int, ...], model_size: int,
               lead_worker: Optional[Tuple[str, ...]] = None,
               fsdp_axis: Optional[str] = None,
               fsdp_size: int = 1) -> P:
    """Spec for one parameter leaf.

    lead_worker: axis 0 is the H-SGD worker axis, sharded over these mesh
    axes (() => leading axis exists but replicated, the degenerate n=1 case).
    fsdp_axis: additionally shard a SECOND weight dim over this axis
    (ZeRO/FSDP within a worker — required for the >=100B archs whose full
    replica does not fit a chip's HBM, and for serving params).
    Stacked-layer leaves carry a scanned unit axis which stays unsharded.
    """
    entries = [None] * len(shape)
    skip = 0
    if lead_worker is not None:
        if len(lead_worker) == 1:
            entries[0] = lead_worker[0]
        elif len(lead_worker) > 1:
            entries[0] = lead_worker
        skip = 1
    if len(shape) - skip >= 2:
        md = _model_dim(shape, model_size, skip_axes=skip)
        if md is not None and shape[md] >= model_size:
            entries[md] = "model"
            if fsdp_axis is not None:
                # secondary: largest remaining dim divisible by fsdp size
                cand = [(shape[i], i) for i in range(skip, len(shape))
                        if i != md and entries[i] is None
                        and shape[i] % fsdp_size == 0 and shape[i] >= fsdp_size]
                if cand:
                    _, fi = max(cand)
                    entries[fi] = fsdp_axis
    return P(*entries)


def params_shardings(mesh, param_specs: Any, *,
                     lead_worker: Optional[Tuple[str, ...]] = None,
                     fsdp_axis: Optional[str] = None,
                     model_shard: bool = True):
    model_size = mesh.shape["model"] if model_shard else 1 << 62
    fsdp_size = mesh.shape[fsdp_axis] if fsdp_axis else 1

    def one(leaf):
        return NamedSharding(mesh, param_spec(
            np.shape(leaf), model_size, lead_worker=lead_worker,
            fsdp_axis=fsdp_axis, fsdp_size=fsdp_size))

    return jax.tree.map(one, param_specs)


def batch_shardings(mesh, batch_specs: Any,
                    lead_worker: Optional[Tuple[str, ...]] = None,
                    data_axis: Optional[str] = None):
    """Training batches (worker, local_batch, ...): worker dim over
    lead_worker axes, local batch over data_axis (fsdp mapping).
    Serving batches (batch, ...): batch over every non-model axis."""
    if lead_worker is None:
        rep = tuple(a for a in mesh.axis_names if a != "model")
        ax0 = rep if len(rep) > 1 else rep[0]

        def one(leaf):
            nd = len(np.shape(leaf))
            return NamedSharding(mesh, P(ax0, *([None] * (nd - 1))))

        return jax.tree.map(one, batch_specs)

    ax0 = (lead_worker if len(lead_worker) > 1
           else (lead_worker[0] if lead_worker else None))

    def one(leaf):
        nd = len(np.shape(leaf))
        entries = [None] * nd
        entries[0] = ax0
        if data_axis is not None and nd >= 2:
            entries[1] = data_axis
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(one, batch_specs)


def cache_shardings(mesh, cache_specs: Any, global_batch: int):
    """Decode caches: shard batch over replica axes when divisible; otherwise
    (long_500k, batch=1) shard the largest remaining dim (the cache sequence
    or the SSM head dim) over them; kv-heads go to 'model' when divisible."""
    model_size = mesh.shape["model"]
    replica = tuple(a for a in mesh.axis_names if a != "model")
    n_rep = int(np.prod([mesh.shape[a] for a in replica]))
    rep_entry = replica if len(replica) > 1 else replica[0]

    def one(path, leaf):
        shape = np.shape(leaf)
        nd = len(shape)
        entries = [None] * nd
        if nd == 0:
            return NamedSharding(mesh, P())
        # locate batch dim: caches are (units, B, ...) or (B, ...); unit axis
        # is scanned. Heuristic: first dim equal to global_batch is batch.
        bdim = next((i for i, s in enumerate(shape) if s == global_batch), None)
        if bdim is not None and global_batch % n_rep == 0:
            entries[bdim] = rep_entry
        else:
            # shard the largest dim divisible by n_rep (cache seq for attn)
            cand = [(s, i) for i, s in enumerate(shape)
                    if i != bdim and s % n_rep == 0 and s >= n_rep]
            if cand:
                _, i = max(cand)
                entries[i] = rep_entry
        # kv heads / feature dims on 'model'
        md = None
        for i in range(nd - 1, -1, -1):
            if entries[i] is None and shape[i] % model_size == 0 \
                    and shape[i] >= model_size:
                md = i
                break
        if md is not None:
            entries[md] = "model"
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map_with_path(one, cache_specs)


def worker_axis_spec(rep_axes: Tuple[str, ...], ndim: int,
                     lead_axis: int = 0) -> P:
    """The one definition of 'the worker axis spans the replica mesh axes':
    dim ``lead_axis`` over ``rep_axes``, every other dim replicated.  Used
    for both device placement (:func:`hsgd_state_shardings`) and the mesh
    executor's shard_map in/out specs, so the two cannot drift."""
    entries = [None] * ndim
    entries[lead_axis] = tuple(rep_axes)
    return P(*entries)


def hsgd_state_shardings(mesh, state: Any):
    """Shardings for H-SGD training state under the mesh executor: every
    array leaf's leading worker axis spans the replica axes (one worker per
    replica-mesh coordinate), remaining dims replicated — within-worker
    'model' TP composes on top via :func:`params_shardings` once the loss is
    written with named-axis collectives.  Scalars (state.step) replicate.
    The worker-axis order is row-major over the replica axes (outermost
    first) — the same order ``flat_worker_index`` reconstructs inside
    shard_map, which is what lets grouped topologies and runtime masks
    address 'worker j' consistently on any mesh factorization.

    The observability probe buffer (``HSGDState.metrics``) is the one
    exception: its leading dim is ring capacity, not workers, and its rows
    are identical on every shard by construction (the probe's last op is a
    pmean over all replica axes) — it replicates."""
    from repro.core.hsgd import HSGDState
    from repro.launch.mesh import replica_axes
    rep = replica_axes(mesh)

    def one(leaf):
        nd = len(np.shape(leaf))
        if nd == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, worker_axis_spec(rep, nd))

    if isinstance(state, HSGDState) and state.metrics is not None:
        return HSGDState(
            params=jax.tree.map(one, state.params),
            opt_state=jax.tree.map(one, state.opt_state),
            step=NamedSharding(mesh, P()),
            comms=jax.tree.map(one, state.comms),
            metrics=jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                 state.metrics))
    return jax.tree.map(one, state)


def replicated(mesh, specs: Any):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), specs)
