"""Batched serving launcher: load (or init) a model, prefill a batch of
prompts, decode N tokens, report tokens/s.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import restore
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving import DecodeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    if args.ckpt_dir:
        _, tree = restore(args.ckpt_dir, {"params": params})
        params = tree["params"]

    eng = DecodeEngine(model, params, temperature=args.temperature)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len),
                                0, cfg.vocab_size)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_inputs"] = jax.random.normal(
            key, (args.batch, max(1, args.prompt_len // cfg.encoder_frames_ratio),
                  cfg.d_model)).astype(cfg.dtype)
    t0 = time.time()
    res = eng.generate(prompt, args.gen, **kw)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}: {args.batch * args.gen / dt:.1f} tok/s "
          f"({dt:.2f}s total)")
    print("sample:", res.tokens[0][:16].tolist())
    return res


if __name__ == "__main__":
    main()
