"""Decoder-only LM covering dense / moe / ssm / hybrid / vlm families.

Layers are grouped into *pattern units* (``cfg.block_pattern``) that repeat
``cfg.num_pattern_units`` times; unit params are stacked on a leading axis and
the forward pass is ``lax.scan`` over units (HLO size stays flat in depth).
Depth remainders (e.g. recurrentgemma's trailing 2 blocks) are unrolled.

Three entry points per model:
  * ``loss``        — training forward + mean token CE (+ MoE aux)
  * ``prefill``     — full-sequence forward that also fills decode caches
  * ``decode_step`` — one-token step against the caches
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]


def constrain_acts(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Optional residual-stream sharding constraint (cfg.act_pspec), a §Perf
    knob: pins the layout GSPMD must keep between layers instead of letting
    it re-shard (which showed up as per-layer activation all-gathers in the
    baseline HLO)."""
    if cfg.act_pspec is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(*cfg.act_pspec)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x  # no mesh context (CPU tests) -> no-op


# --------------------------------------------------------------------------
# block init / apply
# --------------------------------------------------------------------------
def block_init(key, kind: str, cfg: ModelConfig, cross: bool = False) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": L.norm_init(cfg.d_model, cfg)}
    if kind in ("global", "local"):
        p["attn"] = L.attention_init(ks[0], cfg)
    elif kind == "ssd":
        p["ssd"] = L.ssd_init(ks[0], cfg)
    elif kind == "rglru":
        p["rglru"] = L.rglru_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    if cross:
        p["lnx"] = L.norm_init(cfg.d_model, cfg)
        p["xattn"] = L.attention_init(ks[1], cfg)
    has_mlp = cfg.mlp_variant != "none" and cfg.d_ff > 0 and kind != "ssd"
    if has_mlp:
        p["ln2"] = L.norm_init(cfg.d_model, cfg)
        if cfg.num_experts:
            p["moe"] = L.moe_init(ks[2], cfg)
        else:
            p["mlp"] = L.mlp_init(ks[2], cfg)
    return p


def _mixer_window(kind: str, cfg: ModelConfig) -> Optional[int]:
    return cfg.sliding_window if kind == "local" else None


def block_apply(p: Params, x: jax.Array, kind: str, cfg: ModelConfig, *,
                positions: jax.Array,
                enc_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                self_mask: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence block. Returns (x, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(p["ln1"], x, cfg)
    if kind in ("global", "local"):
        h = L.attention_apply(p["attn"], h, cfg, positions=positions,
                              window=_mixer_window(kind, cfg), mask=self_mask)
    elif kind == "ssd":
        h = L.ssd_apply(p["ssd"], h, cfg)
    elif kind == "rglru":
        h = L.rglru_apply(p["rglru"], h, cfg)
    x = x + h
    if "xattn" in p:
        assert enc_kv is not None
        h = L.apply_norm(p["lnx"], x, cfg)
        sq, sk = h.shape[-2], enc_kv[0].shape[-3]
        full = jnp.ones((sq, sk), bool)
        h = L.attention_apply(p["xattn"], h, cfg, positions=positions,
                              kv=enc_kv, mask=full, use_rope=False)
        x = x + h
    if "ln2" in p:
        h = L.apply_norm(p["ln2"], x, cfg)
        if "moe" in p:
            h, aux = L.moe_apply(p["moe"], h, cfg)
        else:
            h = L.mlp_apply(p["mlp"], h, cfg)
        x = x + h
    return x, aux


# ---- prefill: same forward but emits decode caches -------------------------
def block_prefill(p: Params, x: jax.Array, kind: str, cfg: ModelConfig, *,
                  positions: jax.Array, max_len: int,
                  enc_kv=None) -> Tuple[jax.Array, Params]:
    """Returns (x_out, cache) where cache layout matches block_decode."""
    b, s, _ = x.shape
    h = L.apply_norm(p["ln1"], x, cfg)
    cache: Params = {}
    if kind in ("global", "local"):
        k, v = L.attention_kv(p["attn"], h, cfg, positions=positions)
        if kind == "global":
            pad = max_len - s
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cache = {"k": kc, "v": vc}
        else:
            w = cfg.sliding_window
            # slot j holds the last prompt position p with p % w == j
            idx = np.array([s - 1 - ((s - 1 - j) % w) for j in range(w)])
            valid = idx >= 0
            idx_c = np.where(valid, idx, 0)
            kc = jnp.where(valid[None, :, None, None], k[:, idx_c], 0)
            vc = jnp.where(valid[None, :, None, None], v[:, idx_c], 0)
            slot_pos = jnp.asarray(np.where(valid, idx, -1), jnp.int32)
            cache = {"k": kc, "v": vc, "slot_pos": slot_pos}
    if kind in ("global", "local"):
        h = L.attention_apply(p["attn"], h, cfg, positions=positions,
                              window=_mixer_window(kind, cfg))
    elif kind == "ssd":
        z, xbc, dt, di, ns, nh = L._ssd_split(p["ssd"], h, cfg)
        xbc_conv = jax.nn.silu(L.conv1d_apply(p["ssd"]["conv"], xbc))
        xs, B, C = jnp.split(xbc_conv, [di, di + ns], axis=-1)
        dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["ssd"]["dt_bias"])
        A = -jnp.exp(p["ssd"]["A_log"])
        ph = cfg.ssm_head_dim
        xh = xs.reshape(xs.shape[:-1] + (nh, ph))
        pad = (-s) % cfg.ssm_chunk
        if pad:
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dtp, ((0, 0), (0, pad), (0, 0)))
            B_p = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
            C_p = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        else:
            xh_p, dt_p, B_p, C_p = xh, dtp, B, C
        y, state = L.ssd_scan_ref(xh_p, dt_p, A, B_p, C_p, cfg.ssm_chunk)
        y = y[:, :s] + xh * p["ssd"]["D"][:, None].astype(h.dtype)
        y = y.reshape(xs.shape)
        y = y * jax.nn.silu(z)
        ms = (y.astype(jnp.float32) ** 2).mean(-1, keepdims=True)
        y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)).astype(h.dtype) \
            * p["ssd"]["out_norm"]["scale"].astype(h.dtype)
        wdt = cfg.ssm_conv_width - 1
        conv_buf = xbc[:, -wdt:] if s >= wdt else jnp.pad(
            xbc, ((0, 0), (wdt - s, 0), (0, 0)))
        cache = {"ssm": state, "conv": conv_buf}
        h = y @ p["ssd"]["out_proj"].astype(h.dtype)
    elif kind == "rglru":
        xs = h @ p["rglru"]["in_x"].astype(h.dtype)
        gate = jax.nn.gelu(h @ p["rglru"]["in_gate"].astype(h.dtype))
        xs_pre = xs
        xs = L.conv1d_apply(p["rglru"]["conv"], xs)
        ys, h_final = L.rglru_core(p["rglru"], xs)
        wdt = cfg.conv1d_width - 1
        conv_buf = xs_pre[:, -wdt:] if s >= wdt else jnp.pad(
            xs_pre, ((0, 0), (wdt - s, 0), (0, 0)))
        cache = {"h": h_final, "conv": conv_buf}
        h = (ys * gate) @ p["rglru"]["out"].astype(h.dtype)
    x = x + h
    if "xattn" in p:
        hx = L.apply_norm(p["lnx"], x, cfg)
        sq, sk = hx.shape[-2], enc_kv[0].shape[-3]
        hx = L.attention_apply(p["xattn"], hx, cfg, positions=positions,
                               kv=enc_kv, mask=jnp.ones((sq, sk), bool),
                               use_rope=False)
        x = x + hx
    if "ln2" in p:
        h = L.apply_norm(p["ln2"], x, cfg)
        h = L.moe_apply(p["moe"], h, cfg)[0] if "moe" in p else \
            L.mlp_apply(p["mlp"], h, cfg)
        x = x + h
    return x, cache


def block_decode(p: Params, x: jax.Array, kind: str, cfg: ModelConfig, *,
                 cache: Params, pos: jax.Array,
                 enc_kv=None) -> Tuple[jax.Array, Params]:
    """One-token step. x: (B,1,D); pos: scalar int32 (position being written)."""
    b = x.shape[0]
    h = L.apply_norm(p["ln1"], x, cfg)
    if kind in ("global", "local"):
        k, v = L.attention_kv(p["attn"], h, cfg,
                              positions=jnp.full((b, 1), pos, jnp.int32))
        if kind == "global":
            kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                              (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                              (0, pos, 0, 0))
            cache = {"k": kc, "v": vc}
            smax = kc.shape[1]
            cpos = jnp.arange(smax, dtype=jnp.int32)
            cache_positions = jnp.where(cpos <= pos, cpos, -1)
        else:
            w = cfg.sliding_window
            slot = pos % w
            kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                              (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                              (0, slot, 0, 0))
            slot_pos = jax.lax.dynamic_update_slice(
                cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))
            cache = {"k": kc, "v": vc, "slot_pos": slot_pos}
            cache_positions = slot_pos
        h = L.attention_decode(
            p["attn"], h, cfg, k_cache=cache["k"], v_cache=cache["v"],
            cache_positions=jnp.broadcast_to(cache_positions, (b,) + cache_positions.shape),
            position=jnp.full((b,), pos, jnp.int32))
    elif kind == "ssd":
        h, cache = L.ssd_decode(p["ssd"], h, cfg, cache)
    elif kind == "rglru":
        h, cache = L.rglru_decode(p["rglru"], h, cfg, cache)
    x = x + h
    if "xattn" in p:
        hx = L.apply_norm(p["lnx"], x, cfg)
        sk = enc_kv[0].shape[-3]
        hx = L.attention_apply(p["xattn"], hx, cfg,
                               positions=jnp.full((b, 1), pos, jnp.int32),
                               kv=enc_kv, mask=jnp.ones((1, sk), bool),
                               use_rope=False)
        x = x + hx
    if "ln2" in p:
        h = L.apply_norm(p["ln2"], x, cfg)
        h = L.moe_apply_dense(p["moe"], h, cfg) if "moe" in p else \
            L.mlp_apply(p["mlp"], h, cfg)
        x = x + h
    return x, cache


def block_cache_init(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype) -> Params:
    if kind == "global":
        shape = (batch, max_len, cfg.num_kv_heads, cfg.d_head)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kind == "local":
        w = cfg.sliding_window
        shape = (batch, w, cfg.num_kv_heads, cfg.d_head)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "slot_pos": jnp.full((w,), -1, jnp.int32)}
    if kind == "ssd":
        return L.ssd_init_state(cfg, batch, dtype)
    if kind == "rglru":
        return L.rglru_init_state(cfg, batch, dtype)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# the decoder-only LM
# --------------------------------------------------------------------------
class DecoderLM:
    """Unified decoder-only LM. Stateless: params/caches are explicit."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---- init ----------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 4 + len(cfg.pattern_remainder))
        emb = (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02
               ).astype(cfg.param_dtype)
        params: Params = {"embed": emb, "final_norm": L.norm_init(cfg.d_model, cfg)}
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(ks[1], cfg.d_model, cfg.vocab_size,
                                             cfg.param_dtype)
        n_units = cfg.num_pattern_units
        unit_keys = jax.random.split(ks[2], n_units)

        def init_unit(k):
            kk = jax.random.split(k, len(cfg.block_pattern))
            return tuple(block_init(kk[j], kind, cfg)
                         for j, kind in enumerate(cfg.block_pattern))

        params["units"] = jax.vmap(init_unit)(unit_keys) if n_units else ()
        params["rem"] = tuple(
            block_init(ks[3 + j], kind, cfg)
            for j, kind in enumerate(cfg.pattern_remainder))
        return params

    # ---- helpers ---------------------------------------------------------
    def _embed(self, params, tokens):
        x = params["embed"][tokens].astype(self.cfg.dtype)
        return x

    def _logits(self, params, x):
        x = L.apply_norm(params["final_norm"], x, self.cfg)
        head = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        return x @ head.astype(x.dtype)

    # ---- training --------------------------------------------------------
    def forward(self, params: Params, tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """tokens (B,S) -> (logits (B,S,V), moe_aux scalar)."""
        cfg = self.cfg
        b, s = tokens.shape
        x = self._embed(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def unit_body(carry, unit_params):
            x, aux = carry
            x = constrain_acts(x, cfg)
            for j, kind in enumerate(cfg.block_pattern):
                x, a = block_apply(unit_params[j], x, kind, cfg,
                                   positions=positions)
                aux = aux + a
            return (constrain_acts(x, cfg), aux), None

        body = jax.checkpoint(unit_body) if cfg.remat else unit_body
        aux0 = jnp.zeros((), jnp.float32)
        if cfg.num_pattern_units:
            (x, aux), _ = jax.lax.scan(body, (x, aux0), params["units"])
        else:
            aux = aux0
        for j, kind in enumerate(cfg.pattern_remainder):
            x, a = block_apply(params["rem"][j], x, kind, cfg, positions=positions)
            aux = aux + a
        return self._logits(params, x), aux

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
        logits, aux = self.forward(params, batch["tokens"])
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        tgt = batch["targets"]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        mask = batch.get("mask", jnp.ones_like(tgt, jnp.float32))
        ce = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        total = ce + aux
        return total, {"ce": ce, "moe_aux": aux}

    # ---- serving ---------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=None) -> Params:
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        n_units = cfg.num_pattern_units

        def one(kind):
            return block_cache_init(kind, cfg, batch, max_len, dtype)

        units = tuple(
            jax.tree.map(lambda a: jnp.broadcast_to(a, (n_units,) + a.shape), one(kind))
            for kind in cfg.block_pattern) if n_units else ()
        rem = tuple(one(kind) for kind in cfg.pattern_remainder)
        return {"units": units, "rem": rem,
                "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params: Params, tokens: jax.Array,
                max_len: int) -> Tuple[jax.Array, Params]:
        """Full-sequence forward that fills caches. Returns (last logits, cache)."""
        cfg = self.cfg
        b, s = tokens.shape
        x = self._embed(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def unit_body(x, unit_params):
            x = constrain_acts(x, cfg)
            caches = []
            for j, kind in enumerate(cfg.block_pattern):
                x, c = block_prefill(unit_params[j], x, kind, cfg,
                                     positions=positions, max_len=max_len)
                caches.append(c)
            return constrain_acts(x, cfg), tuple(caches)

        if cfg.num_pattern_units:
            x, unit_caches = jax.lax.scan(unit_body, x, params["units"])
        else:
            unit_caches = ()
        rem_caches = []
        for j, kind in enumerate(cfg.pattern_remainder):
            x, c = block_prefill(params["rem"][j], x, kind, cfg,
                                 positions=positions, max_len=max_len)
            rem_caches.append(c)
        logits = self._logits(params, x[:, -1:, :])
        cache = {"units": unit_caches, "rem": tuple(rem_caches),
                 "pos": jnp.asarray(s, jnp.int32)}
        return logits[:, 0], cache

    def decode_step(self, params: Params, cache: Params,
                    token: jax.Array) -> Tuple[jax.Array, Params]:
        """token (B,) int32 -> (logits (B,V), cache)."""
        cfg = self.cfg
        x = self._embed(params, token[:, None])
        pos = cache["pos"]

        def unit_body(x, scanned):
            unit_params, unit_cache = scanned
            new_caches = []
            for j, kind in enumerate(cfg.block_pattern):
                x, c = block_decode(unit_params[j], x, kind, cfg,
                                    cache=unit_cache[j], pos=pos)
                new_caches.append(c)
            return x, tuple(new_caches)

        if cfg.num_pattern_units:
            x, unit_caches = jax.lax.scan(unit_body, x,
                                          (params["units"], cache["units"]))
        else:
            unit_caches = ()
        rem_caches = []
        for j, kind in enumerate(cfg.pattern_remainder):
            x, c = block_decode(params["rem"][j], x, kind, cfg,
                                cache=cache["rem"][j], pos=pos)
            rem_caches.append(c)
        logits = self._logits(params, x)[:, 0]
        return logits, {"units": unit_caches, "rem": tuple(rem_caches),
                        "pos": pos + 1}
