from repro.models.model import build_model, decode_state_specs, input_specs, train_batch_specs
from repro.models.simple import SimpleConfig, SimpleModel

__all__ = ["build_model", "decode_state_specs", "input_specs",
           "train_batch_specs", "SimpleConfig", "SimpleModel"]
