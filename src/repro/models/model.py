"""Unified model API: ``build_model(cfg)`` and ``input_specs(cfg, shape)``.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input of a
given (arch, input-shape) pair — weak-type-correct, shardable, no device
allocation — which is what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.frontends import audio_frame_specs
from repro.models.transformer import DecoderLM


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return DecoderLM(cfg)


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.family == "encdec":
        specs["enc_inputs"] = audio_frame_specs(cfg, shape)
    return specs


def decode_state_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Specs for (cache, token) of a one-token serve step with a seq_len cache."""
    model = build_model(cfg)
    b = shape.global_batch
    if cfg.family == "encdec":
        enc_len = max(1, shape.seq_len // cfg.encoder_frames_ratio)
        cache = jax.eval_shape(
            lambda: model.init_cache(b, shape.seq_len, enc_len=enc_len))
    else:
        cache = jax.eval_shape(lambda: model.init_cache(b, shape.seq_len))
    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    return {"cache": cache, "token": token}


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32)}
        if cfg.family == "encdec":
            specs["enc_inputs"] = audio_frame_specs(cfg, shape)
        return specs
    return decode_state_specs(cfg, shape)
