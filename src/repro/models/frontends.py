"""Modality frontend STUBS (the one allowed carve-out).

- audio (seamless): mel-spectrogram + conv feature extractor is NOT built;
  ``audio_frame_specs`` provides precomputed frame embeddings.
- vlm (chameleon): early fusion — the VQ-VAE tokenizer is NOT built; images
  arrive as ordinary token ids inside the shared 65536 vocab, so the "stub"
  is simply mixed token ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig


def audio_frame_specs(cfg: ModelConfig, shape: InputShape) -> jax.ShapeDtypeStruct:
    frames = max(1, shape.seq_len // cfg.encoder_frames_ratio)
    return jax.ShapeDtypeStruct((shape.global_batch, frames, cfg.d_model),
                                jnp.dtype(cfg.dtype))


def synth_audio_frames(key, cfg: ModelConfig, batch: int, frames: int) -> jax.Array:
    return jax.random.normal(key, (batch, frames, cfg.d_model)).astype(cfg.dtype)
