"""Model-layer primitives shared by every architecture family.

Pure-jnp, batch-first, no explicit collectives: distribution comes from the
shardings of params/inputs (GSPMD). Every function works under nested vmap.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# initializers / norms
# --------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def norm_init(d: int, cfg: ModelConfig) -> Params:
    p = {"scale": jnp.ones((d,), dtype=cfg.param_dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype=cfg.param_dtype)
    return p


def apply_norm(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                      # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]                      # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA, causal, optional sliding window)
# --------------------------------------------------------------------------
def attention_init(key, cfg: ModelConfig, cross: bool = False) -> Params:
    d, hq, hk = cfg.d_model, cfg.num_heads * cfg.d_head, cfg.num_kv_heads * cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq, cfg.param_dtype),
        "wk": dense_init(ks[1], d, hk, cfg.param_dtype),
        "wv": dense_init(ks[2], d, hk, cfg.param_dtype),
        "wo": dense_init(ks[3], hq, d, cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq,), cfg.param_dtype)
        p["bk"] = jnp.zeros((hk,), cfg.param_dtype)
        p["bv"] = jnp.zeros((hk,), cfg.param_dtype)
    return p


def _split_heads(x: jax.Array, n: int, dh: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, dh))


def _gqa_repeat(k: jax.Array, n_rep: int) -> jax.Array:
    """(..., S, Hk, Dh) -> (..., S, Hk*n_rep, Dh) by repeat."""
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=-2)


_ATTN_CHUNK_Q = 512  # default q-block size for the memory-bounded jnp path


def _attn_core(q, k, v, mask, softcap: Optional[float],
               chunk_q: int = _ATTN_CHUNK_Q) -> jax.Array:
    """q: (..., Sq, Hq, Dh); k,v: (..., Sk, Hq, Dh); mask: (..., Sq, Sk) bool.

    Long sequences take a q-chunked path (scan over query blocks) so the
    materialized logits stay O(chunk * Sk) instead of O(Sq * Sk) — the jnp
    analogue of the Pallas flash kernel's VMEM blocking, and what keeps the
    32k/500k dry-run memory analysis honest.
    """
    sq = q.shape[-3]
    if (sq > chunk_q and sq % chunk_q == 0
            and (mask is None or mask.ndim == 2)):
        return _attn_core_chunked(q, k, v, mask, softcap, chunk_q)
    return _attn_core_dense(q, k, v, mask, softcap)


def _attn_core_dense(q, k, v, mask, softcap: Optional[float]) -> jax.Array:
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("...qhd,...khd->...hqk", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    if mask is not None:
        logits = jnp.where(mask[..., None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("...hqk,...khd->...qhd", probs.astype(v.dtype), v)
    return out


def _attn_core_chunked(q, k, v, mask, softcap, chunk: int) -> jax.Array:
    b_dims = q.shape[:-3]
    sq, h, d = q.shape[-3:]
    nc = sq // chunk
    qc = q.reshape(b_dims + (nc, chunk, h, d))
    qc = jnp.moveaxis(qc, len(b_dims), 0)                  # (nc, ..., chunk, H, D)
    mc = mask.reshape(nc, chunk, mask.shape[-1]) if mask is not None else None

    @jax.checkpoint  # recompute chunk probs in backward: no O(Sq*Sk) residuals
    def body(_, xs):
        if mc is None:
            qi = xs
            mi = None
        else:
            qi, mi = xs
        return None, _attn_core_dense(qi, k, v, mi, softcap)

    _, outs = jax.lax.scan(body, None, qc if mc is None else (qc, mc))
    return jnp.moveaxis(outs, 0, len(b_dims)).reshape(q.shape)


def causal_mask(sq: int, sk: int, window: Optional[int] = None,
                q_offset: int = 0) -> jax.Array:
    """bool (sq, sk): True where attend. q position i attends k position j iff
    j <= i+q_offset and (window is None or i+q_offset - j < window)."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= (qpos - kpos) < window
    return m


def attention_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                    positions: jax.Array,
                    window: Optional[int] = None,
                    kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                    mask: Optional[jax.Array] = None,
                    use_rope: bool = True) -> jax.Array:
    """Full-sequence attention (training / prefill).

    x: (B, S, D).  kv: optional precomputed (k, v) for cross-attention
    (already head-split, rope-free).  mask overrides the causal default.
    """
    nh, nkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    explicit_mask = mask is not None
    q = x @ p["wq"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    q = _split_heads(q, nh, dh)
    if kv is None:
        k = x @ p["wk"].astype(x.dtype)
        v = x @ p["wv"].astype(x.dtype)
        if cfg.qkv_bias:
            k = k + p["bk"].astype(x.dtype)
            v = v + p["bv"].astype(x.dtype)
        k = _split_heads(k, nkv, dh)
        v = _split_heads(v, nkv, dh)
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        if mask is None:
            mask = causal_mask(x.shape[-2], x.shape[-2], window)
    else:
        k, v = kv
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
    if (cfg.use_pallas and kv is None and not explicit_mask
            and cfg.attn_logit_softcap is None and x.ndim == 3):
        from repro.kernels import flash_attention  # hot-spot kernel path
        out = flash_attention(q, k, v, causal=True, window=window)
    else:
        k = _gqa_repeat(k, nh // nkv)
        v = _gqa_repeat(v, nh // nkv)
        out = _attn_core(q, k, v, mask, cfg.attn_logit_softcap,
                         chunk_q=cfg.attn_chunk_q)
    out = out.reshape(out.shape[:-2] + (nh * dh,))
    return out @ p["wo"].astype(x.dtype)


def attention_kv(p: Params, x: jax.Array, cfg: ModelConfig, *,
                 positions: Optional[jax.Array] = None,
                 use_rope: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Project k,v (head-split, rope applied if requested) for cache fill."""
    nkv, dh = cfg.num_kv_heads, cfg.d_head
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    k = _split_heads(k, nkv, dh)
    v = _split_heads(v, nkv, dh)
    if use_rope:
        assert positions is not None
        k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def attention_decode(p: Params, x: jax.Array, cfg: ModelConfig, *,
                     k_cache: jax.Array, v_cache: jax.Array,
                     cache_positions: jax.Array, position: jax.Array,
                     use_rope: bool = True) -> jax.Array:
    """One-token decode. x: (B, 1, D); caches: (B, Sc, Hk, Dh);
    cache_positions: (B, Sc) int32 with -1 for empty slots (masked out);
    position: (B,) current absolute position of the new token."""
    nh, nkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    q = x @ p["wq"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    q = _split_heads(q, nh, dh)                          # (B,1,Hq,Dh)
    if use_rope:
        q = apply_rope(q, position[..., None], cfg.rope_theta)
    k = _gqa_repeat(k_cache.astype(x.dtype), nh // nkv)
    v = _gqa_repeat(v_cache.astype(x.dtype), nh // nkv)
    mask = (cache_positions <= position[..., None]) & (cache_positions >= 0)
    out = _attn_core(q, k, v, mask[..., None, :], cfg.attn_logit_softcap)
    out = out.reshape(out.shape[:-2] + (nh * dh,))
    return out @ p["wo"].astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs (swiglu / geglu / relu2 / gelu) and MoE
# --------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_variant in ("swiglu", "geglu"):
        return {"wi": dense_init(ks[0], d, f, cfg.param_dtype),
                "wg": dense_init(ks[1], d, f, cfg.param_dtype),
                "wo": dense_init(ks[2], f, d, cfg.param_dtype)}
    return {"wi": dense_init(ks[0], d, f, cfg.param_dtype),
            "wo": dense_init(ks[2], f, d, cfg.param_dtype)}


def mlp_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.mlp_variant == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * (x @ p["wi"].astype(x.dtype))
    elif cfg.mlp_variant == "geglu":
        h = jax.nn.gelu(x @ p["wg"].astype(x.dtype)) * (x @ p["wi"].astype(x.dtype))
    elif cfg.mlp_variant == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["wi"].astype(x.dtype)))
    else:  # gelu
        h = jax.nn.gelu(x @ p["wi"].astype(x.dtype))
    return h @ p["wo"].astype(x.dtype)


def moe_init(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    glu = cfg.mlp_variant in ("swiglu", "geglu")
    p = {"router": dense_init(ks[0], d, e, jnp.float32),
         "wi": (jax.random.normal(ks[1], (e, d, f)) / np.sqrt(d)).astype(cfg.param_dtype),
         "wo": (jax.random.normal(ks[2], (e, f, d)) / np.sqrt(f)).astype(cfg.param_dtype)}
    if glu:
        p["wg"] = (jax.random.normal(ks[3], (e, d, f)) / np.sqrt(d)).astype(cfg.param_dtype)
    return p


_MOE_GROUP = 2048  # GShard-style token group: capacity & dispatch per group


def moe_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Capacity-factor top-k MoE (GShard-style einsum dispatch).

    x: (B, S, D) -> (y, aux_loss).  FLOPs scale with top_k * capacity_factor,
    not with num_experts (dispatch is one-hot).  Long sequences are processed
    in token groups of _MOE_GROUP (capacity applies per group, exactly the
    GShard 'group' semantics) so the (T, E, C) dispatch tensor stays bounded.
    """
    b, s, d = x.shape
    t = b * s
    group = cfg.moe_group
    if t > group and t % group == 0:
        xt = x.reshape(t // group, group, d)

        def body(_, xg):
            yg, auxg = _moe_group(p, xg, cfg)
            return None, (yg, auxg)

        _, (y, aux) = jax.lax.scan(body, None, xt)
        return y.reshape(b, s, d), aux.mean()
    y, aux = _moe_group(p, x.reshape(t, d), cfg)
    return y.reshape(b, s, d), aux


def _moe_group(p: Params, xt: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    t, d = xt.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = max(1, int(cfg.capacity_factor * t * k / e))

    logits = (xt.astype(jnp.float32) @ p["router"])               # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                  # (T, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(0)                                             # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce) * cfg.router_aux_loss_coef

    # position of each (token, slot) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)          # (T, k, E)
    flat = onehot.reshape(t * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(t, k, e)
    pos = (pos_in_expert * onehot).sum(-1)                         # (T, k)
    in_cap = (pos < cap) & (onehot.sum(-1) > 0)

    if cfg.moe_dispatch == "gather":
        return _moe_gather_path(p, xt, cfg, cap, gate_idx, gate_vals, pos,
                                in_cap), aux

    # dispatch tensor (T, E, C) one-hot; combine weights folded in
    pos_oh = jax.nn.one_hot(pos, cap, dtype=xt.dtype)              # (T, k, C)
    disp = jnp.einsum("tke,tkc->tec",
                      (onehot * in_cap[..., None]).astype(xt.dtype), pos_oh)
    expert_in = jnp.einsum("tec,td->ecd", disp, xt)                # (E, C, D)

    if "wg" in p:
        act = jax.nn.silu if cfg.mlp_variant == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", expert_in, p["wg"].astype(xt.dtype))) * \
            jnp.einsum("ecd,edf->ecf", expert_in, p["wi"].astype(xt.dtype))
    else:
        h = jnp.square(jax.nn.relu(
            jnp.einsum("ecd,edf->ecf", expert_in, p["wi"].astype(xt.dtype))))
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(xt.dtype))

    combine = jnp.einsum("tec,tk,tke->tec", disp,
                         gate_vals.astype(xt.dtype),
                         onehot.astype(xt.dtype))
    y = jnp.einsum("tec,ecd->td", combine, expert_out)
    return y, aux


def _moe_gather_path(p: Params, xt: jax.Array, cfg: ModelConfig, cap: int,
                     gate_idx: jax.Array, gate_vals: jax.Array,
                     pos: jax.Array, in_cap: jax.Array) -> jax.Array:
    """Index-based dispatch/combine: replaces the two O(T*E*C*d) one-hot
    einsums with an (E, C) token-id scatter + gathers.  Identical numerics
    (tested against the einsum path)."""
    t, d = xt.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    tok_ids = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k))
    flat_e = gate_idx.reshape(-1)
    flat_pos = jnp.where(in_cap.reshape(-1), pos.reshape(-1), cap)  # OOB slot
    flat_tok = tok_ids.reshape(-1)
    # slot -> token id (t == padding token). extra capacity column absorbs
    # the dropped assignments; (e, pos) pairs are unique among in-capacity.
    slot_tok = jnp.full((e, cap + 1), t, jnp.int32)
    slot_tok = slot_tok.at[flat_e, flat_pos].set(flat_tok.astype(jnp.int32))
    slot_tok = slot_tok[:, :cap]                                   # (E, C)
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)])
    expert_in = xt_pad[slot_tok]                                   # (E, C, D)

    if "wg" in p:
        act = jax.nn.silu if cfg.mlp_variant == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", expert_in, p["wg"].astype(xt.dtype))) * \
            jnp.einsum("ecd,edf->ecf", expert_in, p["wi"].astype(xt.dtype))
    else:
        h = jnp.square(jax.nn.relu(
            jnp.einsum("ecd,edf->ecf", expert_in, p["wi"].astype(xt.dtype))))
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(xt.dtype))

    # combine: y[t] = sum_k gate[t,k] * expert_out[e(t,k), pos(t,k)]
    pos_c = jnp.minimum(pos, cap - 1)                              # (T, k)
    picked = expert_out[gate_idx, pos_c]                           # (T, k, D)
    w = (gate_vals * in_cap).astype(xt.dtype)                      # (T, k)
    return jnp.einsum("tk,tkd->td", w, picked)


def moe_apply_dense(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """All-expert weighted MoE for decode steps (tiny token counts, where
    capacity dispatch would drop tokens).  FLOPs ~ E/k higher than dispatch,
    acceptable because decode is bandwidth-bound; production serving would use
    ragged dispatch (noted in DESIGN.md)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    xt = x.reshape(b * s, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
    full_gates = jnp.zeros((b * s, e), x.dtype).at[
        jnp.arange(b * s)[:, None], gate_idx].set(gate_vals.astype(x.dtype))
    if "wg" in p:
        act = jax.nn.silu if cfg.mlp_variant == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("td,edf->tef", xt, p["wg"].astype(x.dtype))) * \
            jnp.einsum("td,edf->tef", xt, p["wi"].astype(x.dtype))
    else:
        h = jnp.square(jax.nn.relu(
            jnp.einsum("td,edf->tef", xt, p["wi"].astype(x.dtype))))
    yall = jnp.einsum("tef,efd->ted", h, p["wo"].astype(x.dtype))
    y = jnp.einsum("te,ted->td", full_gates, yall)
    return y.reshape(b, s, d)


# --------------------------------------------------------------------------
# depthwise causal conv1d (shared by ssd / rglru)
# --------------------------------------------------------------------------
def conv1d_init(key, channels: int, width: int, dtype) -> Params:
    return {"w": (jax.random.normal(key, (width, channels)) / np.sqrt(width)).astype(dtype),
            "b": jnp.zeros((channels,), dtype)}


def conv1d_apply(p: Params, x: jax.Array) -> jax.Array:
    """x: (B, S, C). Causal depthwise conv, width from params."""
    w = p["w"].astype(x.dtype)
    width = w.shape[0]
    xpad = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(width - 1, 0), (0, 0)])
    out = sum(xpad[..., i:i + x.shape[-2], :] * w[i] for i in range(width))
    return out + p["b"].astype(x.dtype)


def conv1d_step(p: Params, buf: jax.Array, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Decode step. buf: (B, width-1, C) past inputs; x: (B, C)."""
    w = p["w"].astype(x.dtype)
    width = w.shape[0]
    window = jnp.concatenate([buf, x[..., None, :]], axis=-2)      # (B, width, C)
    out = jnp.einsum("...wc,wc->...c", window, w) + p["b"].astype(x.dtype)
    return window[..., -(width - 1):, :] if width > 1 else buf, out


# --------------------------------------------------------------------------
# Mamba-2 SSD block
# --------------------------------------------------------------------------
def ssd_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    nh = di // cfg.ssm_head_dim
    ns = cfg.ssm_state
    ks = jax.random.split(key, 4)
    conv_dim = di + 2 * ns
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * ns + nh, cfg.param_dtype),
        "conv": conv1d_init(ks[1], conv_dim, cfg.ssm_conv_width, cfg.param_dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_norm": {"scale": jnp.ones((di,), cfg.param_dtype)},
        "out_proj": dense_init(ks[2], di, d, cfg.param_dtype),
    }


def _ssd_split(p: Params, x: jax.Array, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ns = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * ns], axis=-1)
    return z, xbc, dt, di, ns, nh


def ssd_scan_ref(x, dt, A, B, C, chunk: int):
    """Chunked SSD (jnp oracle, also the model's default path).

    x: (Bt, S, H, P); dt: (Bt, S, H) (already softplus'ed, >=0);
    A: (H,) negative; B, C: (Bt, S, N).
    Returns y: (Bt, S, H, P) and final state (Bt, H, P, N).
    """
    bt, s, h, p = x.shape
    n = B.shape[-1]
    q = chunk
    assert s % q == 0, (s, q)
    nc = s // q
    xc = x.reshape(bt, nc, q, h, p)
    dtc = dt.reshape(bt, nc, q, h)
    Bc = B.reshape(bt, nc, q, n)
    Cc = C.reshape(bt, nc, q, n)

    dA = dtc * A  # (bt, nc, q, h) negative
    cum = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum
    # intra-chunk (dual quadratic form)
    LT = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (bt,nc,q_i,q_j,h) = sum_{j<..<=i}
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.exp(jnp.where(causal[None, None, :, :, None], LT, -jnp.inf))
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)           # (bt,nc,q,q)
    M = G[..., None] * decay * dtc[:, :, None, :, :]    # (bt,nc,i,j,h)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xc)

    # inter-chunk recurrence over states
    chunk_decay = jnp.exp(cum[:, :, -1])                # (bt,nc,h)
    # state contribution of each chunk: sum_j exp(sum_{k>j} dA) dt_j B_j x_j
    rev = jnp.exp(cum[:, :, -1:, :] - cum)              # (bt,nc,q,h) decay j->end
    state_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                             dtc * rev, Bc, xc)          # (bt,nc,h,p,n)

    def step(carry, inp):
        s_prev = carry
        dec, sc = inp
        s_new = s_prev * dec[..., None, None] + sc
        return s_new, s_prev

    init = jnp.zeros((bt, h, p, n), jnp.float32)
    final, s_prevs = jax.lax.scan(
        step, init,
        (jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32),
         jnp.moveaxis(state_chunk, 1, 0).astype(jnp.float32)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)               # (bt,nc,h,p,n) state entering chunk
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         Cc.astype(jnp.float32), jnp.exp(cum), s_prevs)
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(bt, s, h, p)
    return y.astype(x.dtype), final


def ssd_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Training/prefill forward. x: (B, S, D) -> (B, S, D)."""
    z, xbc, dt, di, ns, nh = _ssd_split(p, x, cfg)
    xbc = jax.nn.silu(conv1d_apply(p["conv"], xbc))
    xs, B, C = jnp.split(xbc, [di, di + ns], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    ph = cfg.ssm_head_dim
    xh = xs.reshape(xs.shape[:-1] + (nh, ph))
    s = xh.shape[1]
    if cfg.use_pallas and xh.ndim == 4:
        from repro.kernels import ssd_scan  # hot-spot kernel path
        y = ssd_scan(xh, dt, A, B, C, chunk=cfg.ssm_chunk)
        pad = 0
    elif (pad := (-s) % cfg.ssm_chunk):
        y, _ = ssd_scan_ref(
            jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            A,
            jnp.pad(B, ((0, 0), (0, pad), (0, 0))),
            jnp.pad(C, ((0, 0), (0, pad), (0, 0))), cfg.ssm_chunk)
        y = y[:, :s]
    else:
        y, _ = ssd_scan_ref(xh, dt, A, B, C, cfg.ssm_chunk)
    y = y + xh * p["D"][:, None].astype(x.dtype)
    y = y.reshape(xs.shape)
    # gated rmsnorm
    y = y * jax.nn.silu(z)
    ms = (y.astype(jnp.float32) ** 2).mean(-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)).astype(x.dtype) \
        * p["out_norm"]["scale"].astype(x.dtype)
    return y @ p["out_proj"].astype(x.dtype)


def ssd_decode(p: Params, x: jax.Array, cfg: ModelConfig,
               state: Params) -> Tuple[jax.Array, Params]:
    """One-step decode. x: (B, 1, D); state: {'ssm': (B,H,P,N), 'conv': (B,w-1,C)}."""
    z, xbc, dt, di, ns, nh = _ssd_split(p, x, cfg)
    conv_buf, xbc1 = conv1d_step(p["conv"], state["conv"], xbc[:, 0])
    xbc1 = jax.nn.silu(xbc1)
    xs, B, C = jnp.split(xbc1, [di, di + ns], axis=-1)     # (B, di/ns)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    ph = cfg.ssm_head_dim
    xh = xs.reshape(xs.shape[:-1] + (nh, ph))              # (B,H,P)
    dA = jnp.exp(dt1 * A)                                  # (B,H)
    s = state["ssm"] * dA[..., None, None] + \
        jnp.einsum("bh,bn,bhp->bhpn", dt1.astype(x.dtype), B, xh)
    y = jnp.einsum("bn,bhpn->bhp", C, s) + xh * p["D"][:, None].astype(x.dtype)
    y = y.reshape(x.shape[0], di)
    y = y * jax.nn.silu(z[:, 0])
    ms = (y.astype(jnp.float32) ** 2).mean(-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)).astype(x.dtype) \
        * p["out_norm"]["scale"].astype(x.dtype)
    y = (y @ p["out_proj"].astype(x.dtype))[:, None, :]
    return y, {"ssm": s, "conv": conv_buf}


def ssd_init_state(cfg: ModelConfig, batch: int, dtype) -> Params:
    di = cfg.ssm_expand * cfg.d_model
    nh = di // cfg.ssm_head_dim
    conv_dim = di + 2 * cfg.ssm_state
    return {"ssm": jnp.zeros((batch, nh, cfg.ssm_head_dim, cfg.ssm_state), dtype),
            "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype)}


# --------------------------------------------------------------------------
# RG-LRU block (RecurrentGemma / Griffin)
# --------------------------------------------------------------------------
_RGLRU_C = 8.0


def rglru_init(key, cfg: ModelConfig) -> Params:
    d, w = cfg.d_model, cfg.rglru_width
    ks = jax.random.split(key, 6)
    # Lambda init so that a = sigmoid(L)^c is in (0.9, 0.999)
    u = jax.random.uniform(ks[0], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log((u ** (1.0 / _RGLRU_C)) / (1 - u ** (1.0 / _RGLRU_C)))
    return {
        "in_x": dense_init(ks[1], d, w, cfg.param_dtype),
        "in_gate": dense_init(ks[2], d, w, cfg.param_dtype),
        "conv": conv1d_init(ks[3], w, cfg.conv1d_width, cfg.param_dtype),
        "w_a": dense_init(ks[4], w, w, cfg.param_dtype),
        "w_i": dense_init(ks[5], w, w, cfg.param_dtype),
        "Lambda": lam.astype(jnp.float32),
        "out": dense_init(jax.random.fold_in(key, 7), w, d, cfg.param_dtype),
    }


def rglru_gates(p: Params, xs: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """RG-LRU gate computation -> (a, b) of h_t = a_t h_{t-1} + b_t."""
    r = jax.nn.sigmoid(xs @ p["w_a"].astype(xs.dtype))     # recurrence gate
    i = jax.nn.sigmoid(xs @ p["w_i"].astype(xs.dtype))     # input gate
    # a_t = sigmoid(Lambda)^(c * r_t)  computed in log space for stability
    log_a = _RGLRU_C * r.astype(jnp.float32) * jax.nn.log_sigmoid(p["Lambda"])
    a = jnp.exp(log_a)                                     # (B,S,W) in (0,1)
    gated = (i * xs).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
    return a, b


def rglru_core(p: Params, xs: jax.Array,
               h0: Optional[jax.Array] = None,
               use_pallas: bool = False) -> Tuple[jax.Array, jax.Array]:
    """The RG-LRU recurrence. xs: (B, S, W) -> (ys, h_final)."""
    a, b = rglru_gates(p, xs)

    if use_pallas and h0 is None and xs.ndim == 3:
        from repro.kernels import rglru_scan  # hot-spot kernel path
        bb = rglru_scan(a, b)
        return bb.astype(xs.dtype), bb[..., -1, :]

    # linear recurrence h_t = a_t h_{t-1} + b_t  via associative scan over S
    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    aa, bb = jax.lax.associative_scan(comb, (a, b), axis=-2)
    if h0 is not None:
        bb = bb + aa * h0[..., None, :].astype(jnp.float32)
    h_final = bb[..., -1, :]
    return bb.astype(xs.dtype), h_final


def rglru_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Training/prefill. x: (B, S, D)."""
    xs = x @ p["in_x"].astype(x.dtype)
    gate = jax.nn.gelu(x @ p["in_gate"].astype(x.dtype))
    xs = conv1d_apply(p["conv"], xs)
    ys, _ = rglru_core(p, xs, use_pallas=cfg.use_pallas)
    return (ys * gate) @ p["out"].astype(x.dtype)


def rglru_decode(p: Params, x: jax.Array, cfg: ModelConfig,
                 state: Params) -> Tuple[jax.Array, Params]:
    """x: (B, 1, D); state: {'h': (B, W), 'conv': (B, w-1, W)}."""
    xs = (x[:, 0] @ p["in_x"].astype(x.dtype))
    gate = jax.nn.gelu(x[:, 0] @ p["in_gate"].astype(x.dtype))
    conv_buf, xs = conv1d_step(p["conv"], state["conv"], xs)
    r = jax.nn.sigmoid(xs @ p["w_a"].astype(xs.dtype))
    i = jax.nn.sigmoid(xs @ p["w_i"].astype(xs.dtype))
    a = jnp.exp(_RGLRU_C * r.astype(jnp.float32) * jax.nn.log_sigmoid(p["Lambda"]))
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xs).astype(jnp.float32)
    h = a * state["h"].astype(jnp.float32) + b
    y = (h.astype(x.dtype) * gate) @ p["out"].astype(x.dtype)
    return y[:, None, :], {"h": h, "conv": conv_buf}


def rglru_init_state(cfg: ModelConfig, batch: int, dtype) -> Params:
    w = cfg.rglru_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype)}
