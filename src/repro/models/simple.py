"""The paper's own experiment models, at CPU scale.

The paper trains VGG-11 (CIFAR-10 / CelebA) and a 9-layer CNN (FEMNIST).
Offline we reproduce the *claims* (sandwich behaviour, grouping effects,
multi-level) with the same loss geometry: softmax CE classifiers on non-IID
data — an MLP (VGG stand-in) and a small CNN (FEMNIST stand-in).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SimpleConfig:
    kind: str = "mlp"          # 'mlp' | 'cnn' | 'linear'
    input_dim: int = 32        # mlp/linear: features; cnn: image side
    channels: int = 1
    hidden: int = 64
    num_classes: int = 10


def _dense(key, din, dout):
    return {"w": jax.random.normal(key, (din, dout)) / np.sqrt(din),
            "b": jnp.zeros((dout,))}


class SimpleModel:
    def __init__(self, cfg: SimpleConfig):
        self.cfg = cfg

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        if cfg.kind == "linear":
            return {"out": _dense(ks[0], cfg.input_dim, cfg.num_classes)}
        if cfg.kind == "mlp":
            return {"h1": _dense(ks[0], cfg.input_dim, cfg.hidden),
                    "h2": _dense(ks[1], cfg.hidden, cfg.hidden),
                    "out": _dense(ks[2], cfg.hidden, cfg.num_classes)}
        # cnn: two 3x3 convs + pool + dense (the paper's FEMNIST CNN, shrunk)
        c = cfg.channels
        return {
            "c1": {"w": jax.random.normal(ks[0], (3, 3, c, 8)) / 3.0,
                   "b": jnp.zeros((8,))},
            "c2": {"w": jax.random.normal(ks[1], (3, 3, 8, 16)) / np.sqrt(72),
                   "b": jnp.zeros((16,))},
            "out": _dense(ks[2], (cfg.input_dim // 4) ** 2 * 16, cfg.num_classes),
        }

    def logits(self, params, x):
        cfg = self.cfg
        if cfg.kind == "linear":
            return x @ params["out"]["w"] + params["out"]["b"]
        if cfg.kind == "mlp":
            h = jax.nn.relu(x @ params["h1"]["w"] + params["h1"]["b"])
            h = jax.nn.relu(h @ params["h2"]["w"] + params["h2"]["b"])
            return h @ params["out"]["w"] + params["out"]["b"]
        h = x.reshape(x.shape[0], cfg.input_dim, cfg.input_dim, cfg.channels)
        for name in ("c1", "c2"):
            h = jax.lax.conv_general_dilated(
                h, params[name]["w"], (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + params[name]["b"]
            h = jax.nn.relu(h)
            h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        h = h.reshape(h.shape[0], -1)
        return h @ params["out"]["w"] + params["out"]["b"]

    def loss(self, params, batch) -> Tuple[jax.Array, Dict]:
        lg = self.logits(params, batch["x"])
        logp = jax.nn.log_softmax(lg)
        nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1).mean()
        return nll, {"ce": nll}

    def accuracy(self, params, batch) -> jax.Array:
        lg = self.logits(params, batch["x"])
        return (jnp.argmax(lg, -1) == batch["y"]).mean()
