"""Encoder-decoder backbone (SeamlessM4T family).

The modality frontend is a stub: the encoder consumes precomputed frame
embeddings (batch, frames, d_model) — see ``repro.models.frontends``.
Decoder = standard blocks + per-layer cross-attention over encoder memory.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.transformer import (DecoderLM, block_apply, block_cache_init,
                                      block_decode, block_init, block_prefill)

Params = Dict[str, Any]


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.num_encoder_layers > 0
        self.cfg = cfg

    # ---- init -----------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        emb = (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02
               ).astype(cfg.param_dtype)
        params: Params = {
            "embed": emb,
            "final_norm": L.norm_init(cfg.d_model, cfg),
            "enc_norm": L.norm_init(cfg.d_model, cfg),
            "lm_head": L.dense_init(ks[1], cfg.d_model, cfg.vocab_size,
                                    cfg.param_dtype),
        }
        enc_keys = jax.random.split(ks[2], cfg.num_encoder_layers)
        params["enc_units"] = jax.vmap(
            lambda k: block_init(k, "global", cfg))(enc_keys)
        dec_keys = jax.random.split(ks[3], cfg.num_layers)
        params["dec_units"] = jax.vmap(
            lambda k: block_init(k, "global", cfg, cross=True))(dec_keys)
        return params

    # ---- encoder ----------------------------------------------------------
    def encode(self, params: Params, enc_inputs: jax.Array) -> jax.Array:
        """enc_inputs: (B, F, D) stub frame embeddings -> memory (B, F, D)."""
        cfg = self.cfg
        b, f, _ = enc_inputs.shape
        x = enc_inputs.astype(cfg.dtype)
        positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))
        full = jnp.ones((f, f), bool)  # bidirectional

        def body(x, p):
            x, _ = block_apply(p, x, "global", cfg, positions=positions,
                               self_mask=full)
            return x, None

        x, _ = jax.lax.scan(body, x, params["enc_units"])
        return L.apply_norm(params["enc_norm"], x, cfg)

    # ---- training ----------------------------------------------------------
    def forward(self, params: Params, tokens: jax.Array,
                enc_inputs: jax.Array) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        memory = self.encode(params, enc_inputs)
        b, s = tokens.shape
        x = params["embed"][tokens].astype(cfg.dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def body(x, p):
            kv = L.attention_kv(p["xattn"], memory, cfg, use_rope=False)
            x, _ = block_apply(p, x, "global", cfg, positions=positions,
                               enc_kv=kv)
            return x, None

        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, params["dec_units"])
        x = L.apply_norm(params["final_norm"], x, cfg)
        logits = x @ params["lm_head"].astype(x.dtype)
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
        logits, aux = self.forward(params, batch["tokens"], batch["enc_inputs"])
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        tgt = batch["targets"]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        mask = batch.get("mask", jnp.ones_like(tgt, jnp.float32))
        ce = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return ce, {"ce": ce, "moe_aux": aux}

    # ---- serving ------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=None,
                   enc_len: int = 0) -> Params:
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        n = cfg.num_layers
        one = block_cache_init("global", cfg, batch, max_len, dtype)
        units = jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)
        enc_len = enc_len or max_len // cfg.encoder_frames_ratio
        xshape = (n, batch, enc_len, cfg.num_kv_heads, cfg.d_head)
        units = {**units, "xk": jnp.zeros(xshape, dtype),
                 "xv": jnp.zeros(xshape, dtype)}
        return {"units": units, "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params: Params, tokens: jax.Array, max_len: int, *,
                enc_inputs: jax.Array) -> Tuple[jax.Array, Params]:
        cfg = self.cfg
        memory = self.encode(params, enc_inputs)
        b, s = tokens.shape
        x = params["embed"][tokens].astype(cfg.dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def body(x, p):
            xk, xv = L.attention_kv(p["xattn"], memory, cfg, use_rope=False)
            x, c = block_prefill(p, x, "global", cfg, positions=positions,
                                 max_len=max_len, enc_kv=(xk, xv))
            return x, {**c, "xk": xk, "xv": xv}

        x, unit_caches = jax.lax.scan(body, x, params["dec_units"])
        x = L.apply_norm(params["final_norm"], x[:, -1:, :], cfg)
        logits = (x @ params["lm_head"].astype(x.dtype))[:, 0]
        return logits, {"units": unit_caches, "pos": jnp.asarray(s, jnp.int32)}

    def decode_step(self, params: Params, cache: Params,
                    token: jax.Array) -> Tuple[jax.Array, Params]:
        cfg = self.cfg
        x = params["embed"][token[:, None]].astype(cfg.dtype)
        pos = cache["pos"]

        def body(x, scanned):
            p, c = scanned
            enc_kv = (c["xk"], c["xv"])
            x, cc = block_decode(p, x, "global", cfg, cache={"k": c["k"], "v": c["v"]},
                                 pos=pos, enc_kv=enc_kv)
            return x, {**cc, "xk": c["xk"], "xv": c["xv"]}

        x, unit_caches = jax.lax.scan(body, x, (params["dec_units"], cache["units"]))
        x = L.apply_norm(params["final_norm"], x, cfg)
        logits = (x @ params["lm_head"].astype(x.dtype))[:, 0]
        return logits, {"units": unit_caches, "pos": pos + 1}
