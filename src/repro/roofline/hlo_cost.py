"""Recursive HLO cost model with while-loop trip-count awareness.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body ONCE, which
undercounts scanned-layer models by the layer count (verified in tests).
This module parses the compiled HLO text and recursively analyses the entry
computation:

  * dot            — 2 * result_elems * contraction_size
  * convolution    — 2 * result_elems * prod(kernel dims) / out_features
  * elementwise    — result_elems (minor; dots dominate)
  * fusion/call    — cost of the called computation
  * while          — trip_count * (body + condition)   <- the fix
  * collectives    — result bytes, split intra/cross-pod, trip-scaled

Bytes follow XLA's "bytes accessed" convention on the optimized module:
per top-level instruction, operands read + result written (fusion internals
excluded).  Validated against cost_analysis() on loop-free modules in tests.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "tanh", "exponential",
    "log", "rsqrt", "sqrt", "maximum", "minimum", "negate", "abs", "floor",
    "ceil", "sign", "cosine", "sine", "logistic", "atan2", "remainder",
    "and", "or", "xor", "not", "select", "clamp", "compare",
    "exponential-minus-one", "log-plus-one", "cbrt", "erf",
}
_REDUCE_LIKE = {"reduce", "reduce-window"}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_ATOM = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+(.*)$")
_IOTA_RG = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]"
                      r"(?:T\(([\d,]+)\))?")
_BRACE_RG = re.compile(r"replica_groups=\{(\{[\d,]*\})")


@dataclasses.dataclass
class Instr:
    name: str
    shapes: List[Tuple[str, Tuple[int, ...]]]  # result shapes (tuple-flattened)
    op: str
    operands: List[str]
    attrs: str
    raw: str = ""

    def result_elems(self) -> int:
        return sum(int(np.prod(s)) if s else 1 for _, s in self.shapes)

    def result_bytes(self) -> int:
        return sum((int(np.prod(s)) if s else 1) * _DTYPE_BYTES.get(dt, 0)
                   for dt, s in self.shapes)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_intra: float = 0.0
    coll_cross: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_intra += o.coll_intra
        self.coll_cross += o.coll_cross
        for k in _COLLECTIVES:
            self.coll_by_kind[k] += o.coll_by_kind[k]
        return self

    def scaled(self, t: float) -> "Cost":
        return Cost(self.flops * t, self.bytes * t, self.coll_intra * t,
                    self.coll_cross * t,
                    {k: v * t for k, v in self.coll_by_kind.items()})


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_ATOM.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


_OPNAME = re.compile(r"^([a-z][\w\-]*)\(")


def parse_module(text: str) -> Tuple[Dict[str, List[Instr]], Optional[str]]:
    comps: Dict[str, List[Instr]] = {}
    entry = None
    cur: Optional[str] = None
    for line in text.splitlines():
        m = _COMP_START.match(line.strip()) if "{" in line else None
        if m and ("->" in line):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        # split rhs into "<shape> op(...)..." — find the op token
        # shape part ends at the first " <opname>(" occurrence
        om = re.search(r"\s([a-z][a-z0-9\-]*)\(", rhs)
        if not om:
            continue
        op = om.group(1)
        shape_txt = rhs[:om.start()]
        rest = rhs[om.end():]
        # operand names: inside the first balanced (...) after op
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        operand_txt = rest[:i - 1] if i else ""
        attrs = rest[i:]
        operands = re.findall(r"%([\w\.\-]+)", operand_txt)
        comps[cur].append(Instr(name, _parse_shapes(shape_txt), op,
                                operands, attrs, raw=rhs))
    return comps, entry


class ModuleCost:
    def __init__(self, text: str, pod_size: int = 256):
        self.comps, self.entry = parse_module(text)
        self.pod_size = pod_size
        self._memo: Dict[str, Cost] = {}
        # scalar integer constants per computation (for while trip counts)
        self._const: Dict[str, Dict[str, int]] = {}
        for cname, instrs in self.comps.items():
            d = {}
            for ins in instrs:
                if ins.op == "constant":
                    m = re.search(r"constant\((\d+)\)", ins.raw)
                    if m:
                        d[ins.name] = int(m.group(1))
            self._const[cname] = d

    # -- helpers -----------------------------------------------------------
    def _defs(self, cname: str) -> Dict[str, Instr]:
        return {i.name: i for i in self.comps[cname]}

    def _operand_bytes(self, cname: str, ins: Instr) -> int:
        defs = self._defs(cname)
        total = 0
        for op in ins.operands:
            d = defs.get(op)
            if d is not None:
                total += d.result_bytes()
        return total

    def _access_bytes(self, cname: str, ins: Instr) -> float:
        """XLA-convention bytes accessed for one top-level instruction.
        Slicing ops read only what they produce; dynamic-update-slice writes
        only the update (the big buffer is aliased)."""
        defs = self._defs(cname)
        op = ins.op
        if op in ("slice", "dynamic-slice", "gather"):
            return 2.0 * ins.result_bytes()
        if op == "dynamic-update-slice":
            upd = defs.get(ins.operands[1]) if len(ins.operands) > 1 else None
            ub = upd.result_bytes() if upd else ins.result_bytes()
            return 2.0 * ub
        if op == "fusion":
            m = re.search(r"calls=%([\w\.\-]+)", ins.attrs)
            inner_name = m.group(1) if m else None
            total = float(ins.result_bytes())
            inner = self.comps.get(inner_name, []) if inner_name else []
            # map fusion operand i -> inner parameter(i); if every inner use
            # of that parameter is a slicing op, charge the sliced bytes only
            params: Dict[int, str] = {}
            for iins in inner:
                if iins.op == "parameter":
                    pm = re.match(r"^\s*(\d+)\s*\)", iins.attrs) or \
                        re.search(r"parameter\((\d+)\)", iins.raw)
                    if pm:
                        params[int(pm.group(1))] = iins.name
            for idx, opnd in enumerate(ins.operands):
                d = defs.get(opnd)
                if d is None:
                    continue
                pname = params.get(idx)
                charged = d.result_bytes()
                if pname is not None:
                    users = [u for u in inner if pname in u.operands]
                    if users and all(u.op in ("slice", "dynamic-slice",
                                              "gather", "dynamic-update-slice")
                                     for u in users):
                        charged = sum(
                            (self._defs(inner_name)[u.operands[1]].result_bytes()
                             if u.op == "dynamic-update-slice"
                             and len(u.operands) > 1
                             and u.operands[1] in self._defs(inner_name)
                             else u.result_bytes())
                            for u in users)
                total += charged
            return total
        return float(self._operand_bytes(cname, ins) + ins.result_bytes())

    def _dot_flops(self, cname: str, ins: Instr) -> float:
        defs = self._defs(cname)
        lhs = defs.get(ins.operands[0]) if ins.operands else None
        if lhs is None or not lhs.shapes:
            return 2.0 * ins.result_elems()
        lhs_shape = lhs.shapes[0][1]
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        contract = 1
        if m and m.group(1):
            for di in m.group(1).split(","):
                di = int(di)
                if di < len(lhs_shape):
                    contract *= lhs_shape[di]
        return 2.0 * ins.result_elems() * contract

    def _conv_flops(self, cname: str, ins: Instr) -> float:
        defs = self._defs(cname)
        rhs = defs.get(ins.operands[1]) if len(ins.operands) > 1 else None
        if rhs is None or not rhs.shapes:
            return 2.0 * ins.result_elems()
        rhs_shape = rhs.shapes[0][1]
        # out-features: the 'o' dim of dim_labels rhs part (e.g. b0f_0io->b0f)
        m = re.search(r"dim_labels=\w+_(\w+)->", ins.attrs)
        o_size = 1
        if m:
            labels = m.group(1)
            oi = labels.index("o") if "o" in labels else None
            if oi is not None and oi < len(rhs_shape):
                o_size = rhs_shape[oi]
        kernel = int(np.prod(rhs_shape)) // max(o_size, 1)
        return 2.0 * ins.result_elems() * kernel

    def _trip(self, cond_name: str) -> int:
        return max([1] + list(self._const.get(cond_name, {}).values()))

    def _collective(self, ins: Instr) -> Tuple[float, bool]:
        nbytes = ins.result_bytes()
        cross = False
        m = _IOTA_RG.search(ins.attrs)
        if m:
            g, s = int(m.group(1)), int(m.group(2))
            dims = [int(x) for x in m.group(3).split(",")]
            perm = ([int(x) for x in m.group(4).split(",")]
                    if m.group(4) else list(range(len(dims))))
            ids = np.arange(int(np.prod(dims))).reshape(dims)
            ids = ids.transpose(perm).reshape(g, s)
            pods = ids // self.pod_size
            cross = bool((pods != pods[:, :1]).any())
        else:
            mb = _BRACE_RG.search(ins.attrs)
            if mb:
                ids = [int(x) for x in re.findall(r"\d+", mb.group(1))]
                if ids and max(ids) // self.pod_size != min(ids) // self.pod_size:
                    cross = True
        return nbytes, cross

    # -- main recursion ------------------------------------------------------
    def cost_of(self, cname: str) -> Cost:
        if cname in self._memo:
            return self._memo[cname]
        self._memo[cname] = Cost()  # cycle guard
        total = Cost()
        for ins in self.comps.get(cname, []):
            op = ins.op
            if op in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "copy", "after-all", "iota"):
                # zero-flop; copies do move bytes
                if op == "copy":
                    total += Cost(bytes=2.0 * ins.result_bytes())
                continue
            if op in ("while", "call", "conditional"):
                # control flow: charge only the inner computations (the
                # carried tuple is aliased, not re-materialized per step)
                base = Cost()
            else:
                base = Cost(bytes=self._access_bytes(cname, ins))
            if op == "dot":
                base.flops = self._dot_flops(cname, ins)
            elif op == "convolution":
                base.flops = self._conv_flops(cname, ins)
            elif op == "fusion":
                m = re.search(r"calls=%([\w\.\-]+)", ins.attrs)
                if m:
                    inner = self.cost_of(m.group(1))
                    base.flops = inner.flops
                    base.coll_intra = inner.coll_intra
                    base.coll_cross = inner.coll_cross
                    base.coll_by_kind = dict(inner.coll_by_kind)
            elif op == "call":
                m = re.search(r"to_apply=%([\w\.\-]+)", ins.attrs)
                if m:
                    base += self.cost_of(m.group(1))
            elif op == "conditional":
                mb = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
                if mb:
                    branches = re.findall(r"%([\w\.\-]+)", mb.group(1))
                    if branches:  # charge the most expensive branch
                        costs = [self.cost_of(b) for b in branches]
                        base += max(costs, key=lambda c: c.flops + c.bytes)
            elif op == "while":
                mb = re.search(r"body=%([\w\.\-]+)", ins.attrs)
                mc = re.search(r"condition=%([\w\.\-]+)", ins.attrs)
                if mb and mc:
                    trip = self._trip(mc.group(1))
                    inner = self.cost_of(mb.group(1)) \
                        .scaled(trip)
                    inner += self.cost_of(mc.group(1)).scaled(trip)
                    base += inner
            elif any(op.startswith(c) for c in _COLLECTIVES):
                if op.endswith("-done"):
                    continue
                kind = next(c for c in _COLLECTIVES if op.startswith(c))
                nbytes, cross = self._collective(ins)
                base.coll_by_kind[kind] += nbytes
                if cross:
                    base.coll_cross += nbytes
                else:
                    base.coll_intra += nbytes
            elif op in _ELEMENTWISE:
                base.flops = float(ins.result_elems())
            elif op in _REDUCE_LIKE:
                base.flops = float(self._operand_bytes(cname, ins)) / 4.0
            total += base
        self._memo[cname] = total
        return total

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.cost_of(self.entry)


def analyze_hlo(text: str, pod_size: int = 256) -> Cost:
    return ModuleCost(text, pod_size=pod_size).entry_cost()
