"""Three-term roofline from a compiled (dry-run) artifact.

  compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = collective_bytes_per_chip / link_bw
                    (cross-pod collectives priced at DCI bandwidth)

The SPMD-partitioned module is per-device, so cost_analysis() and the HLO
shapes are already per-chip.  collective_bytes is NOT in cost_analysis —
we parse the compiled HLO text and sum result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# "f32[2,16,128]{2,1,0}" or bare "f32[]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# result = <shape-or-tuple> <op>( ... which op names start the rhs
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
# iota format: replica_groups=[16,4]<=[2,4,8]T(0,2,1)
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _iota_groups_cross_pod(m, pod_size: int) -> bool:
    g, s = int(m.group(1)), int(m.group(2))
    dims = [int(x) for x in m.group(3).split(",")]
    perm = ([int(x) for x in m.group(4).split(",")]
            if m.group(4) else list(range(len(dims))))
    ids = np.arange(int(np.prod(dims))).reshape(dims).transpose(perm).reshape(g, s)
    pods = ids // pod_size
    return bool((pods != pods[:, :1]).any())


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e-like chip (task-provided constants)."""
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # B/s per chip
    ici_bw: float = 50e9            # B/s per link (intra-pod)
    dci_bw: float = 25e9            # B/s (cross-pod)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, pod_size: int = 256) -> Dict[str, float]:
    """Per-chip bytes by collective kind, split intra/cross-pod via
    replica_groups span ( -start ops counted once; -done skipped)."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    out["cross_pod"] = 0.0
    out["intra_pod"] = 0.0
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_txt)
        out[kind] += nbytes
        cross = False
        im = _IOTA_RE.search(line)
        if im:
            cross = _iota_groups_cross_pod(im, pod_size)
        else:
            gm = _GROUPS_RE.search(line)
            if gm:
                first = gm.group(1)
                ids = [int(x) for x in re.findall(r"\d+", first.split("}")[0])]
                if ids and (max(ids) // pod_size) != (min(ids) // pod_size):
                    cross = True
        out["cross_pod" if cross else "intra_pod"] += nbytes
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineReport:
    name: str
    flops_per_chip: float
    bytes_per_chip: float
    coll_intra: float
    coll_cross: float
    coll_by_kind: Dict[str, float]
    peak_memory_bytes: Optional[float]
    hw: HW = dataclasses.field(default_factory=HW)

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / self.hw.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_chip / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.coll_intra / self.hw.ici_bw + self.coll_cross / self.hw.dci_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """No-overlap estimate of a step (sum is pessimistic; max is the
        perfectly-overlapped bound — we report both)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def asdict(self) -> Dict:
        return {
            "name": self.name,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_intra_bytes": self.coll_intra,
            "coll_cross_bytes": self.coll_cross,
            "coll_by_kind": self.coll_by_kind,
            "peak_memory_bytes": self.peak_memory_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s_overlapped": self.step_s,
        }


def analyze_compiled(name: str, compiled, pod_size: int = 256,
                     hw: HW = HW()) -> RooflineReport:
    """Uses the trip-count-aware HLO cost model (repro.roofline.hlo_cost):
    XLA's cost_analysis() counts while bodies once, undercounting scanned-
    layer models by the layer count."""
    from repro.roofline.hlo_cost import analyze_hlo
    hlo = compiled.as_text()
    c = analyze_hlo(hlo, pod_size=pod_size)
    flops = c.flops
    byts = c.bytes
    coll = {"intra_pod": c.coll_intra, "cross_pod": c.coll_cross,
            **c.coll_by_kind}
    peak = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            peak = float(getattr(ma, "temp_size_in_bytes", 0) +
                         getattr(ma, "argument_size_in_bytes", 0) +
                         getattr(ma, "output_size_in_bytes", 0) -
                         getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return RooflineReport(
        name=name, flops_per_chip=flops, bytes_per_chip=byts,
        coll_intra=coll["intra_pod"], coll_cross=coll["cross_pod"],
        coll_by_kind={k: coll[k] for k in _COLLECTIVES}, peak_memory_bytes=peak,
        hw=hw)


def combine_train_steps(reports: Dict[str, RooflineReport], G: int,
                        I: int) -> Dict[str, float]:
    """Amortized H-SGD step over one global period:
    (G - G/I) pure-local + (G/I - 1) local-sync + 1 global-sync steps.
    M=1 hierarchies (fsdp mapping) have no local sync: local stands in."""
    lsync = reports.get("local_sync", reports["local"])
    n_local = G - G // I
    n_lsync = G // I - 1
    out = {}
    for term in ("compute_s", "memory_s", "collective_s"):
        tot = (n_local * getattr(reports["local"], term)
               + n_lsync * getattr(lsync, term)
               + getattr(reports["global_sync"], term))
        out[term] = tot / G
    out["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                          key=lambda t: out[t])
    return out
