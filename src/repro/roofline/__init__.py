from repro.roofline.analysis import (HW, RooflineReport, analyze_compiled,
                                     collective_bytes, combine_train_steps)

__all__ = ["HW", "RooflineReport", "analyze_compiled", "collective_bytes",
           "combine_train_steps"]
