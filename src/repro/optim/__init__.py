from repro.optim.optimizers import Optimizer, adam, momentum, sgd
from repro.optim.schedule import constant, cosine, linear_warmup

__all__ = ["Optimizer", "adam", "momentum", "sgd",
           "constant", "cosine", "linear_warmup"]
