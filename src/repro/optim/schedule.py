"""LR schedules as ``step -> lr`` callables."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup_steps: int):
    def f(step):
        s = step.astype(jnp.float32)
        return lr * jnp.minimum(1.0, (s + 1.0) / max(warmup_steps, 1))
    return f


def cosine(lr: float, total_steps: int, warmup_steps: int = 0,
           final_fraction: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (s + 1.0) / max(warmup_steps, 1)) if warmup_steps \
            else 1.0
        frac = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        cos = final_fraction + (1 - final_fraction) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return lr * warm * cos
    return f
