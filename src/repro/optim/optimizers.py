"""Pure-JAX optimizers (optax-style, no dependency).

``update`` returns the delta to ADD to params. The LR may be a float or a
schedule ``step -> float``; ``step`` is threaded through opt_state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: Schedule, step: jax.Array) -> jax.Array:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]  # (grads, state, params) -> (updates, state)


def sgd(lr: Schedule) -> Optimizer:
    """Plain SGD — the paper's optimizer (Algorithm 1 line 5)."""
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        del params
        g = _lr_at(lr, state["step"])
        upd = jax.tree.map(lambda x: (-g * x.astype(jnp.float32)).astype(x.dtype), grads)
        return upd, {"step": state["step"] + 1}

    return Optimizer(init, update)


def momentum(lr: Schedule, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)}

    def update(grads, state, params):
        del params
        g = _lr_at(lr, state["step"])
        m = jax.tree.map(lambda mi, gi: beta * mi + gi.astype(jnp.float32),
                         state["m"], grads)
        if nesterov:
            upd = jax.tree.map(
                lambda mi, gi, xi: (-g * (beta * mi + gi.astype(jnp.float32))
                                    ).astype(xi.dtype), m, grads, grads)
        else:
            upd = jax.tree.map(lambda mi, gi: (-g * mi).astype(gi.dtype), m, grads)
        return upd, {"step": state["step"] + 1, "m": m}

    return Optimizer(init, update)


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda x: jnp.zeros_like(x, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        g = _lr_at(lr, state["step"])
        m = jax.tree.map(lambda mi, gi: b1 * mi + (1 - b1) * gi.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda vi, gi: b2 * vi + (1 - b2) * jnp.square(
            gi.astype(jnp.float32)), state["v"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(mi, vi, pi):
            u = (mi / c1) / (jnp.sqrt(vi / c2) + eps)
            if weight_decay:
                u = u + weight_decay * pi.astype(jnp.float32)
            return (-g * u).astype(pi.dtype)

        return (jax.tree.map(upd, m, v, params),
                {"step": step, "m": m, "v": v})

    return Optimizer(init, update)
