"""Tracing a live :class:`~repro.core.hsgd.HSGD` engine into a report.

``audit_engine`` walks one global period of the engine's schedule, traces
every distinct SyncEvent's aggregation subprogram
(``executor.sync_jaxpr``) and every distinct Round's fused program
(``executor.round_jaxpr``), and derives the schedule-level expectations the
rules check against.  Where no exact expectation exists — grouped
topologies, weighted aggregators, ``exact=True`` replay — the audit records
the measured numbers with ``expected_* = None`` and leaves enforcement to
the budget diff (any drift from the committed baseline still fails CI).

The sim/mesh asymmetry is deliberate: under the mesh executor the sync IS
the named-axis collectives; under sim the sync is in-array reduces over the
worker axis, so sim payload figures are divided by the worker count to get
the same per-worker units the mesh reports natively.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.analysis.report import EventAudit, RoundAudit, SyncPlanReport
from repro.analysis.rules import run_rules
from repro.analysis.walker import walk


def event_key(event) -> str:
    if event.groups is None:
        return f"L{event.level}"
    return f"L{event.level}@" + ",".join(str(g) for g in event.groups)


def round_key(rnd) -> str:
    ev = "none" if rnd.event is None else event_key(rnd.event)
    return f"r{rnd.n_local}+{ev}"


def _encode_keys(aggregator) -> int:
    """How many wire arrays the aggregator's encode splits a value into
    (mean → 1; sign → 2: sign + magnitude)."""
    return len(aggregator.encode(jnp.zeros((1, 1), jnp.float32)))


def _sync_parts(eng, state):
    from repro.core.hsgd import _moments_only
    parts = [state.params]
    if eng.aggregate_opt_state:
        moments = _moments_only(state.opt_state)
        if jax.tree.leaves(moments):
            parts.append(moments)
    return parts


def _expected_sync_ops(eng, state, backend: str = "sim") -> Optional[int]:
    """Per-sync aggregation-op prediction, or None when no exact one exists.

    Legacy roundtrip lowering: ``n_arrays × encode-keys`` — dtype buckets
    per part with fused comms on, leaves per part without.  When the sync
    lowers as a compressed collective (:func:`~repro.core.executors.
    _wire_eligible`), the codec owns the count instead:
    ``n_arrays × codec.lowered_sync_ops(backend)`` (int8 = quantized psum
    [+ scale pmax under mesh], sign = vote + scale, ...).  Weighted
    aggregators add a denominator reduction per array and ``exact=True``
    replays the whole sim reduce under one gather — neither has a clean
    closed form, so both defer to the budget."""
    topo = eng.topology
    if getattr(topo, "spec", None) is None:
        return None  # grouped topologies: membership-matrix path
    if getattr(eng.executor, "exact", False):
        return None
    agg = topo.aggregator
    if agg.worker_weights(topo.n) is not None:
        return None
    if eng.comms is not None and eng.comms.bucket:
        from repro.comms import FlatBucket
        n_arrays = sum(len(FlatBucket.plan(p).lengths)
                       for p in _sync_parts(eng, state))
        from repro.core.executors import _wire_eligible
        from repro.core.topology import SyncEvent
        if _wire_eligible(eng, SyncEvent(level=1)):
            codec = eng.comms.codec
            per_array = codec.lowered_sync_ops(backend)
            if per_array is not None:
                if (codec.layout_free and not codec.stateful
                        and backend == "sim"):
                    # in-array backends elide the bucket for layout-free
                    # codecs (see Comms.sync): one reduce per LEAF
                    n_arrays = sum(len(jax.tree.leaves(p))
                                   for p in _sync_parts(eng, state))
                return n_arrays * per_array
    else:
        n_arrays = sum(len(jax.tree.leaves(p))
                       for p in _sync_parts(eng, state))
    return n_arrays * _encode_keys(agg)


def _metrics_off_twin(eng):
    """A metrics-off clone of ``eng`` (same topology/comms/runtime/executor
    settings) — the R6 baseline the metrics-on round bodies are diffed
    against."""
    from repro.core.hsgd import HSGD
    return HSGD(eng.loss_fn, eng.optimizer, eng.topology,
                dataclasses.replace(eng.config, metrics=None,
                                    executor=eng.executor.twin(),
                                    comms=eng.comms, runtime=eng.runtime,
                                    population=None))


def audit_engine(eng, state, batch_fn: Optional[Callable[[int], Any]] = None,
                 *, T: Optional[int] = None, config: str = "",
                 waivers: Mapping[str, str] = (),
                 run: bool = True) -> SyncPlanReport:
    """Audit ``eng``'s lowered sync plan; the engine-side entry point is
    :meth:`repro.core.hsgd.HSGD.audit`.

    Traces one global period (or ``T`` steps) of the schedule.  With
    ``batch_fn`` the distinct Rounds are traced too (R3), and with ``run``
    additionally executed once through :meth:`run_rounds` so retrace
    detection (R4) measures real jit-cache growth; without ``batch_fn`` the
    report covers sync subprograms only (R1/R2/R5)."""
    topo, ex = eng.topology, eng.executor
    is_mesh = getattr(ex, "mesh", None) is not None
    n = topo.n
    horizon = int(T) if T else topo.periods[0]
    schedule = topo.schedule(horizon)

    expected_ops = _expected_sync_ops(eng, state,
                                      "mesh" if is_mesh else "sim")
    ws = eng.wire_stats(state)
    wire = None
    if ws is not None:
        wire = {"payload_bytes": ws.payload_bytes,
                "n_elements": ws.n_elements,
                "f32_bytes": ws.f32_bytes,
                "wire_dtypes": list(ws.wire_dtypes)}
    # R5 only has an exact per-worker element prediction when each array is
    # reduced once as-is: single-key encode, no weight denominators, and the
    # identity codec (a compressed collective's counted totals include scale
    # statistics / widened payloads, not the WireStats element count)
    expected_elems = None
    if ws is not None and expected_ops is not None and \
            _encode_keys(topo.aggregator) == 1 and \
            eng.comms is not None and eng.comms.codec.name == "identity":
        expected_elems = ws.n_elements

    events: Dict[str, EventAudit] = {}
    for ev in schedule:
        if ev is None:
            continue
        key = event_key(ev)
        if key in events:
            continue
        summary = walk(ex.sync_jaxpr(ev, state))
        # sim aggregation = worker-axis reduces; reduces INSIDE a codec's
        # Pallas kernel (top-k thresholding etc.) are kernel-internal
        # arithmetic, not aggregation, and are excluded
        ops = summary.collectives if is_mesh else tuple(
            o for o in summary.reduces if "pallas_call" not in o.path)
        elements = sum(o.elements for o in ops)
        nbytes = sum(o.nbytes for o in ops)
        f32_elements = sum(o.elements for o in ops
                           if "float32" in o.dtypes)
        if not is_mesh:  # sim reduces carry the full (n, ...) worker axis
            elements //= n
            nbytes //= n
            f32_elements //= n
        events[key] = EventAudit(
            key=key, level=ev.level, groups=ev.groups,
            sync_ops=len(ops), expected_sync_ops=expected_ops,
            ops=ops,
            axes=tuple(sorted({a for o in ops for a in o.axes})),
            wire_dtypes=tuple(sorted({d for o in ops for d in o.dtypes})),
            payload_elements=elements, payload_bytes=nbytes,
            expected_payload_elements=expected_elems,
            f32_elements=f32_elements)

    rounds: Dict[str, RoundAudit] = {}
    probes = None
    if batch_fn is not None:
        from repro.core.hsgd import Round, compile_schedule
        twin = tstate = None
        if eng.metrics is not None:
            # R6: diff every round body against its metrics-off twin — the
            # probe may add neither host callbacks/transfers nor more than
            # the Metrics plan's declared op budget
            twin = _metrics_off_twin(eng)
            tstate = dataclasses.replace(state, metrics=None)
            probes = {"budget": eng.metrics.op_budget(
                "mesh" if is_mesh else "sim", topo,
                len(jax.tree.leaves(state.params))), "rounds": {}}

        def agg_ops(summary) -> int:
            # same measure as the event audits: named-axis collectives under
            # mesh, in-array reduces (minus codec-kernel internals) under sim
            if is_mesh:
                return summary.collective_count
            return len([o for o in summary.reduces
                        if "pallas_call" not in o.path])

        if run:
            eng.run_rounds(state, batch_fn, horizon)
        for rnd in dict.fromkeys(compile_schedule(schedule)):
            batches = tuple(batch_fn(i) for i in range(rnd.n_local))
            summary = walk(ex.round_jaxpr(rnd, state, batches))
            fn = ex.round_fn(rnd)
            cache_size = getattr(fn, "_cache_size", None)
            rounds[round_key(rnd)] = RoundAudit(
                key=round_key(rnd), n_local=rnd.n_local,
                event=None if rnd.event is None else event_key(rnd.event),
                collective_count=summary.collective_count,
                callbacks=tuple(f"{o.primitive}@{o.path}"
                                for o in summary.callbacks),
                transfers=tuple(f"{o.primitive}@{o.path}"
                                for o in summary.transfers),
                cache_stable=fn is ex.round_fn(Round(rnd.n_local, rnd.event)),
                jit_cache_size=(cache_size() if callable(cache_size) and run
                                else None))
            if twin is not None:
                tsum = walk(twin.executor.round_jaxpr(rnd, tstate, batches))
                probes["rounds"][round_key(rnd)] = {
                    "extra_ops": agg_ops(summary) - agg_ops(tsum),
                    "extra_callbacks":
                        len(summary.callbacks) - len(tsum.callbacks),
                    "extra_transfers":
                        len(summary.transfers) - len(tsum.transfers),
                }

    report = SyncPlanReport(
        config=config,
        executor="mesh" if is_mesh else "sim",
        topology=type(topo).__name__,
        aggregator=type(topo.aggregator).__name__,
        codec=None if eng.comms is None else eng.comms.codec.name,
        events=events, rounds=rounds, wire=wire, probes=probes)
    return dataclasses.replace(
        report, findings=tuple(run_rules(report, waivers)))
