"""Budget gating: diff live audit reports against a committed baseline.

``ANALYSIS_budget.json`` at the repo root pins, per audited configuration,
the comparable numbers of its sync plan — sync-op counts, named axes, wire
dtypes, payload bytes, round collective counts — plus the accepted findings
and the waivers that accept them.  ``python -m repro.analysis --check``
re-audits and fails on any **regression**:

* a new sync event / round signature, or a config missing from the budget
* sync-op or round-collective count growth (new collectives)
* a new operand dtype on a sync op (dtype upcasts)
* payload byte growth (per event or in the declared WireStats payload)
* a changed named-axis set (traffic crossing different mesh links)
* host callbacks / transfers beyond the recorded count
* any unwaived rule finding, and any finding not recorded in the budget

Shrinking numbers are reported as **improvements** — the check still
passes, with a note to re-pin via ``--update`` so the better numbers become
the new floor.  ``--update`` MERGES: waivers and entries for configs not
re-audited on this device count (the 8-dev mesh legs on a 1-dev machine)
are preserved verbatim.

Waiver format: ``budget["waivers"]`` maps an ``fnmatch`` config pattern to
``{rule_id: reason}`` — e.g. ``"*grouped*": {"R1": "..."}``.  The
compressed-collective configs (int8, sign) are deliberately un-waivable:
their R2 burn-down is done, and :func:`check_reports` treats any waiver
pattern that would re-cover them as a regression so the debt cannot quietly
return.
"""
from __future__ import annotations

import json
from fnmatch import fnmatch
from pathlib import Path
from typing import Any, Dict, Iterable, List, Tuple

from repro.analysis.report import SyncPlanReport

BUDGET_FILE = "ANALYSIS_budget.json"

# Configs whose R2 burn-down is complete: the compressed-collective lowering
# keeps the wire dtype on the collective, so re-waiving them (on any
# backend) would hide a real regression.  Probed with fnmatch against every
# waiver pattern in check_reports.
_UNWAIVABLE_PROBES = (
    "sim/two_level/int8", "mesh/two_level/int8",
    "sim/two_level/sign", "mesh/two_level/sign",
)


def load_budget(path) -> Dict[str, Any]:
    path = Path(path)
    if not path.is_file():
        return {"version": 1, "waivers": {}, "configs": {}}
    return json.loads(path.read_text(encoding="utf-8"))


def save_budget(path, budget: Dict[str, Any]) -> None:
    Path(path).write_text(json.dumps(budget, indent=2, sort_keys=True) + "\n",
                          encoding="utf-8")


def waivers_for(budget: Dict[str, Any], config: str) -> Dict[str, str]:
    """Merge every waiver pattern matching ``config`` (specific patterns
    listed later override earlier ones on rule-id collisions)."""
    out: Dict[str, str] = {}
    for pattern, rules in (budget.get("waivers") or {}).items():
        if fnmatch(config, pattern):
            out.update(rules)
    return out


def entry_from_report(report: SyncPlanReport) -> Dict[str, Any]:
    """The comparable (budget-pinned) projection of a report."""
    return {
        "executor": report.executor,
        "codec": report.codec,
        "events": {k: {
            "sync_ops": ev.sync_ops,
            "axes": sorted(ev.axes),
            "wire_dtypes": sorted(ev.wire_dtypes),
            "payload_bytes": ev.payload_bytes,
        } for k, ev in sorted(report.events.items())},
        "rounds": {k: {
            "collective_count": rnd.collective_count,
            "callbacks": len(rnd.callbacks),
            "transfers": len(rnd.transfers),
        } for k, rnd in sorted(report.rounds.items())},
        "wire": None if report.wire is None else {
            "payload_bytes": report.wire["payload_bytes"],
            "wire_dtypes": sorted(report.wire["wire_dtypes"]),
        },
        "probes": None if report.probes is None else {
            "budget": report.probes.get("budget", 0),
            "rounds": {k: dict(v) for k, v in
                       sorted(report.probes.get("rounds", {}).items())},
        },
        "findings": sorted(f"{f.rule}:{f.subject}" for f in report.findings),
    }


def _diff_num(regs, imps, where: str, what: str, now: int, pinned: int):
    if now > pinned:
        regs.append(f"{where}: {what} grew {pinned} -> {now}")
    elif now < pinned:
        imps.append(f"{where}: {what} shrank {pinned} -> {now}")


def _diff_set(regs, imps, where: str, what: str, now, pinned):
    new, gone = sorted(set(now) - set(pinned)), sorted(set(pinned) - set(now))
    if new:
        regs.append(f"{where}: new {what} {new}")
    if gone:
        imps.append(f"{where}: {what} {gone} no longer present")


def diff_entry(config: str, entry: Dict[str, Any],
               pinned: Dict[str, Any]) -> Tuple[List[str], List[str]]:
    """(regressions, improvements) of a live entry vs its pinned baseline."""
    regs: List[str] = []
    imps: List[str] = []
    for kind in ("events", "rounds"):
        now, old = entry.get(kind, {}), pinned.get(kind, {})
        for key in sorted(set(now) - set(old)):
            regs.append(f"{config}: new {kind[:-1]} signature '{key}'")
        for key in sorted(set(old) - set(now)):
            imps.append(f"{config}: {kind[:-1]} '{key}' disappeared")
    for key in sorted(set(entry.get("events", {})) &
                      set(pinned.get("events", {}))):
        now, old = entry["events"][key], pinned["events"][key]
        where = f"{config} sync {key}"
        _diff_num(regs, imps, where, "sync ops", now["sync_ops"],
                  old["sync_ops"])
        _diff_set(regs, imps, where, "wire dtype(s)", now["wire_dtypes"],
                  old["wire_dtypes"])
        _diff_num(regs, imps, where, "payload bytes", now["payload_bytes"],
                  old["payload_bytes"])
        if sorted(now["axes"]) != sorted(old["axes"]):
            regs.append(f"{where}: named axes changed "
                        f"{old['axes']} -> {now['axes']}")
    for key in sorted(set(entry.get("rounds", {})) &
                      set(pinned.get("rounds", {}))):
        now, old = entry["rounds"][key], pinned["rounds"][key]
        where = f"{config} round {key}"
        _diff_num(regs, imps, where, "collectives", now["collective_count"],
                  old["collective_count"])
        _diff_num(regs, imps, where, "host callbacks", now["callbacks"],
                  old["callbacks"])
        _diff_num(regs, imps, where, "device transfers", now["transfers"],
                  old["transfers"])
    if entry.get("wire") and pinned.get("wire"):
        where = f"{config} wire"
        _diff_num(regs, imps, where, "declared payload bytes",
                  entry["wire"]["payload_bytes"],
                  pinned["wire"]["payload_bytes"])
        _diff_set(regs, imps, where, "declared wire dtype(s)",
                  entry["wire"]["wire_dtypes"], pinned["wire"]["wire_dtypes"])
    if entry.get("probes") and pinned.get("probes"):
        # pinned probe-overhead floor: extra ops per round may only shrink;
        # callbacks/transfers are additionally hard-zeroed by rule R6
        now_r = entry["probes"].get("rounds", {})
        old_r = pinned["probes"].get("rounds", {})
        for key in sorted(set(now_r) & set(old_r)):
            where = f"{config} probes {key}"
            _diff_num(regs, imps, where, "extra probe ops",
                      now_r[key].get("extra_ops", 0),
                      old_r[key].get("extra_ops", 0))
        _diff_num(regs, imps, f"{config} probes", "declared op budget",
                  entry["probes"].get("budget", 0),
                  pinned["probes"].get("budget", 0))
    _diff_set(regs, imps, config, "finding(s)", entry.get("findings", ()),
              pinned.get("findings", ()))
    return regs, imps


def check_reports(reports: Iterable[SyncPlanReport],
                  budget: Dict[str, Any]) -> Tuple[List[str], List[str]]:
    """Diff every report against the budget.  Returns (regressions,
    improvements); a check passes iff regressions is empty."""
    regs: List[str] = []
    imps: List[str] = []
    configs = budget.get("configs", {})
    for pattern, rules in (budget.get("waivers") or {}).items():
        hit = sorted(p for p in _UNWAIVABLE_PROBES if fnmatch(p, pattern))
        if hit:
            regs.append(
                f"waiver pattern '{pattern}' ({'/'.join(sorted(rules))}) "
                f"covers compressed-collective config(s) {hit} — their R2 "
                f"burn-down is complete and may not be re-waived")
    for report in reports:
        for f in report.unwaived:
            regs.append(f"{report.config}: unwaived finding {f.rule} "
                        f"{f.subject}: {f.message}")
        if report.config not in configs:
            regs.append(f"{report.config}: not in budget (run --update)")
            continue
        r, i = diff_entry(report.config, entry_from_report(report),
                          configs[report.config])
        regs += r
        imps += i
    return regs, imps


def update_budget(budget: Dict[str, Any],
                  reports: Iterable[SyncPlanReport]) -> Dict[str, Any]:
    """Re-pin the audited configs; everything else (waivers, configs not in
    ``reports``) carries over unchanged."""
    configs = dict(budget.get("configs", {}))
    for report in reports:
        configs[report.config] = entry_from_report(report)
    return {"version": budget.get("version", 1),
            "waivers": dict(budget.get("waivers", {})),
            "configs": configs}
