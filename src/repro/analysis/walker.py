"""The jaxpr walker: structured extraction of what a lowered program ships.

Every perf claim this repo makes about its lowered round programs —
O(dtypes) fused collectives (comms), named-axis lowering per sync level
(mesh), no host round-trips inside the scanned body — used to be verified
by counting substrings of ``str(jax.make_jaxpr(...))`` in individual tests.
This module is the one real implementation those assertions now share: it
recursively walks a (closed) jaxpr — descending into every sub-jaxpr a
primitive carries (``pjit``, ``scan``, ``shard_map``, ``cond`` branches,
``custom_jvp``/``vjp`` calls, ...) — and records the operations that matter
for sync-plan auditing:

* **collectives** — ``psum`` (and its ``check_rep`` rewrite ``psum2``),
  ``pmean``\\*, ``all_gather``, ``all_to_all``, ``ppermute``, ... with their
  named axes, operand dtypes, element counts and bytes.  These ARE the wire
  under the mesh executor.  (\\*``lax.pmean`` lowers to psum + div, so it is
  counted through its psum; ``pbroadcast`` is replication bookkeeping, not
  traffic, and is deliberately excluded.)
* **reduces** — ``reduce_sum`` / ``dot_general``: the in-array reshape-mean
  and membership segment-mean forms the sim executor aggregates with.
* **callbacks** / **transfers** — ``debug_callback``, ``pure_callback``,
  ``io_callback``, ``device_put``, in/outfeed: host round-trips that must
  never appear inside a compiled round body (rule R3).

The result is plain data (:class:`JaxprSummary` of :class:`OpRecord`), so
the rule engine in :mod:`repro.analysis.rules` and all its tests operate on
values, never on live tracers.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import Counter
from typing import Any, Dict, Iterable, Tuple

import jax
import numpy as np

try:  # jax >= 0.4.33: the public IR types
    from jax.extend.core import ClosedJaxpr, Jaxpr
except ImportError:  # pragma: no cover — older jax
    from jax.core import ClosedJaxpr, Jaxpr

# psum2/pbroadcast are what check_rep=True shard_map rewrites psum into.
COLLECTIVE_PRIMS = frozenset({
    "psum", "psum2", "pmean", "pmax", "pmin", "all_gather",
    "all_gather_invariant", "all_to_all", "ppermute", "psum_scatter",
    "reduce_scatter",
})
CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
})
TRANSFER_PRIMS = frozenset({"device_put", "infeed", "outfeed"})
REDUCE_PRIMS = frozenset({"reduce_sum", "dot_general"})


@dataclasses.dataclass(frozen=True)
class OpRecord:
    """One audited equation: where it sits and what it consumes."""
    primitive: str
    path: str                    # "/"-joined enclosing primitives ("" = top)
    axes: Tuple[str, ...]        # named mesh axes (collectives only)
    dtypes: Tuple[str, ...]      # operand dtypes
    elements: int                # total operand elements
    nbytes: int                  # total operand bytes

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "OpRecord":
        return cls(d["primitive"], d["path"], tuple(d["axes"]),
                   tuple(d["dtypes"]), int(d["elements"]), int(d["nbytes"]))


@dataclasses.dataclass(frozen=True)
class JaxprSummary:
    """Everything the walker saw, as plain data."""
    counts: Dict[str, int]             # primitive name -> eqn count
    collectives: Tuple[OpRecord, ...]
    callbacks: Tuple[OpRecord, ...]
    transfers: Tuple[OpRecord, ...]
    reduces: Tuple[OpRecord, ...]

    def count(self, *prims: str) -> int:
        """Total eqn count over the given primitive names."""
        return sum(self.counts.get(p, 0) for p in prims)

    @property
    def collective_count(self) -> int:
        return len(self.collectives)


def _subjaxprs(params: Dict[str, Any]) -> Iterable[Any]:
    for v in params.values():
        if isinstance(v, (Jaxpr, ClosedJaxpr)):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, (Jaxpr, ClosedJaxpr)):
                    yield x


def _operand_stats(eqn) -> Tuple[Tuple[str, ...], int, int]:
    dtypes, elements, nbytes = [], 0, 0
    for var in eqn.invars:
        aval = getattr(var, "aval", None)
        shape = getattr(aval, "shape", None)
        if shape is None:
            continue
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        dt = np.dtype(aval.dtype)
        dtypes.append(dt.name)
        elements += n
        nbytes += n * dt.itemsize
    return tuple(dtypes), elements, nbytes


def _axes(eqn) -> Tuple[str, ...]:
    axes = eqn.params.get("axes") or eqn.params.get("axis_name") or ()
    if isinstance(axes, str):
        axes = (axes,)
    # named mesh axes only — reduce_sum reuses the 'axes' param for
    # positional ints, which are not wire-relevant
    return tuple(a for a in axes if isinstance(a, str))


def walk(jaxpr) -> JaxprSummary:
    """Walk a (Closed)Jaxpr and every nested sub-jaxpr; return the summary."""
    counts: Counter = Counter()
    collectives, callbacks, transfers, reduces = [], [], [], []

    def visit(j, path: str) -> None:
        j = getattr(j, "jaxpr", j)  # ClosedJaxpr -> Jaxpr
        for eqn in j.eqns:
            name = eqn.primitive.name
            counts[name] += 1
            bucket = (collectives if name in COLLECTIVE_PRIMS else
                      callbacks if name in CALLBACK_PRIMS else
                      transfers if name in TRANSFER_PRIMS else
                      reduces if name in REDUCE_PRIMS else None)
            if bucket is not None:
                dtypes, elements, nbytes = _operand_stats(eqn)
                bucket.append(OpRecord(name, path, _axes(eqn), dtypes,
                                       elements, nbytes))
            sub_path = f"{path}/{name}" if path else name
            for sub in _subjaxprs(eqn.params):
                visit(sub, sub_path)

    visit(jaxpr, "")
    return JaxprSummary(dict(counts), tuple(collectives), tuple(callbacks),
                        tuple(transfers), tuple(reduces))


def trace(fn, *args, **kwargs) -> JaxprSummary:
    """``walk(jax.make_jaxpr(fn)(*args))`` — the one-liner the migrated
    test assertions use."""
    return walk(jax.make_jaxpr(fn)(*args, **kwargs))


_ADDR = None  # compiled lazily; "at 0x7f..." object addresses in the print


def fingerprint(jaxpr) -> str:
    """Stable digest of a traced program: two fingerprints are equal iff
    the lowered programs are equation-for-equation identical — the
    'jaxpr-identical' claim tests assert without shipping the whole string
    around.  Object addresses in the pretty-print (``custom_jvp_call``'s
    ``jvp_jaxpr_thunk=<function ... at 0x...>``) differ between otherwise
    identical traces and are scrubbed."""
    global _ADDR
    if _ADDR is None:
        import re
        _ADDR = re.compile(r"0x[0-9a-f]+")
    text = _ADDR.sub("0x", str(jaxpr))
    return hashlib.sha256(text.encode("utf-8")).hexdigest()[:16]
