"""The :class:`SyncPlanReport` — what an engine's lowered programs ship.

Everything here is plain JSON-able data.  The engine (:mod:`.engine`)
produces a report by tracing live executors; the rules (:mod:`.rules`) and
the budget differ (:mod:`.budget`) consume reports — and because a report
round-trips through ``to_dict``/``from_dict``, rule and budget tests can
fabricate arbitrary good/bad reports without ever touching a tracer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.analysis.walker import OpRecord


@dataclasses.dataclass(frozen=True)
class EventAudit:
    """The lowered sync subprogram of ONE distinct SyncEvent.

    ``sync_ops`` counts the operations that realize the aggregation: the
    named-axis collectives under the mesh executor, the in-array reduces
    (``reduce_sum``/``dot_general``) under sim.  ``expected_sync_ops`` is the
    schedule-derived prediction (O(dtype buckets)·keys with comms on,
    O(leaves)·keys without) — None when no exact prediction exists (grouped
    topologies, weighted aggregators, ``exact=True`` replay), in which case
    R1/R5 defer to the budget diff instead.  Payload figures are per worker.
    """
    key: str                              # "L2", "L1@0,2", ...
    level: int
    groups: Optional[Tuple[int, ...]]
    sync_ops: int
    expected_sync_ops: Optional[int]
    ops: Tuple[OpRecord, ...]             # the sync_ops records themselves
    axes: Tuple[str, ...]                 # union of named axes (mesh)
    wire_dtypes: Tuple[str, ...]          # distinct operand dtypes
    payload_elements: int
    payload_bytes: int
    expected_payload_elements: Optional[int]  # from WireStats (R5), if exact
    f32_elements: Optional[int] = None    # elements of float32 sync operands
    #   (R2: a compressing codec must keep f32 a strict minority of the
    #   payload; None on reports predating the field -> R2 dtype fallback)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["ops"] = [o.to_dict() for o in self.ops]
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "EventAudit":
        return cls(
            key=d["key"], level=int(d["level"]),
            groups=None if d.get("groups") is None else tuple(d["groups"]),
            sync_ops=int(d["sync_ops"]),
            expected_sync_ops=(None if d.get("expected_sync_ops") is None
                               else int(d["expected_sync_ops"])),
            ops=tuple(OpRecord.from_dict(o) for o in d.get("ops", ())),
            axes=tuple(d.get("axes", ())),
            wire_dtypes=tuple(d.get("wire_dtypes", ())),
            payload_elements=int(d.get("payload_elements", 0)),
            payload_bytes=int(d.get("payload_bytes", 0)),
            expected_payload_elements=(
                None if d.get("expected_payload_elements") is None
                else int(d["expected_payload_elements"])),
            f32_elements=(None if d.get("f32_elements") is None
                          else int(d["f32_elements"])))


@dataclasses.dataclass(frozen=True)
class RoundAudit:
    """The lowered program of ONE distinct ``Round`` signature.

    ``callbacks``/``transfers`` are ``"primitive@path"`` strings for every
    host callback or device transfer found inside the traced round body
    (rule R3 requires both empty).  ``cache_stable`` asserts the executor
    returns the SAME compiled callable for an equal Round (the plan-layer
    cache); ``jit_cache_size`` is the jit-internal compiled-variant count
    after a ``run_rounds`` pass — >1 means the signature retraced (R4).
    """
    key: str                              # "r4+L1", "r4+none", ...
    n_local: int
    event: Optional[str]                  # EventAudit key, or None
    collective_count: int
    callbacks: Tuple[str, ...]
    transfers: Tuple[str, ...]
    cache_stable: bool
    jit_cache_size: Optional[int]         # None when not measurable

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RoundAudit":
        return cls(
            key=d["key"], n_local=int(d["n_local"]), event=d.get("event"),
            collective_count=int(d.get("collective_count", 0)),
            callbacks=tuple(d.get("callbacks", ())),
            transfers=tuple(d.get("transfers", ())),
            cache_stable=bool(d.get("cache_stable", True)),
            jit_cache_size=(None if d.get("jit_cache_size") is None
                            else int(d["jit_cache_size"])))


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule firing.  ``waived`` findings are known-and-accepted baseline
    facts (recorded in the budget's ``waivers`` with a reason); they stay in
    the report so the debt is visible, but do not fail a ``--check``."""
    rule: str        # "R1".."R5"
    subject: str     # event/round key (or "" for report-wide)
    message: str
    waived: bool = False
    waive_reason: str = ""

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Finding":
        return cls(d["rule"], d.get("subject", ""), d.get("message", ""),
                   bool(d.get("waived", False)), d.get("waive_reason", ""))


@dataclasses.dataclass(frozen=True)
class SyncPlanReport:
    """The full audit of one engine configuration."""
    config: str                            # config name ("sim/two_level/int8")
    executor: str                          # "sim" | "mesh" | class name
    topology: str
    aggregator: str
    codec: Optional[str]                   # codec name, None with comms off
    events: Dict[str, EventAudit]
    rounds: Dict[str, RoundAudit]
    wire: Optional[Dict[str, Any]]         # WireStats-declared accounting
    findings: Tuple[Finding, ...] = ()
    probes: Optional[Dict[str, Any]] = None
    #   metrics-on overhead accounting (rule R6), None when the audited
    #   engine has no observability plan: {"budget": max extra ops the
    #   Metrics plan declares, "rounds": {round key: {"extra_ops",
    #   "extra_callbacks", "extra_transfers"} vs the metrics-off twin}}

    @property
    def unwaived(self) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if not f.waived)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "config": self.config, "executor": self.executor,
            "topology": self.topology, "aggregator": self.aggregator,
            "codec": self.codec,
            "events": {k: v.to_dict() for k, v in sorted(self.events.items())},
            "rounds": {k: v.to_dict() for k, v in sorted(self.rounds.items())},
            "wire": self.wire,
            "findings": [f.to_dict() for f in self.findings],
            "probes": self.probes,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SyncPlanReport":
        return cls(
            config=d.get("config", ""), executor=d.get("executor", ""),
            topology=d.get("topology", ""),
            aggregator=d.get("aggregator", ""), codec=d.get("codec"),
            events={k: EventAudit.from_dict(v)
                    for k, v in d.get("events", {}).items()},
            rounds={k: RoundAudit.from_dict(v)
                    for k, v in d.get("rounds", {}).items()},
            wire=d.get("wire"),
            findings=tuple(Finding.from_dict(f)
                           for f in d.get("findings", ())),
            probes=d.get("probes"))

    # -- display -------------------------------------------------------------
    def summary(self) -> str:
        """Human-readable audit summary (``--audit`` / CLI output)."""
        lines = [f"[{self.config or self.executor}] executor={self.executor} "
                 f"topology={self.topology} aggregator={self.aggregator} "
                 f"codec={self.codec or 'off'}"]
        for key, ev in sorted(self.events.items()):
            exp = ("" if ev.expected_sync_ops is None
                   else f" (expected {ev.expected_sync_ops})")
            axes = f" axes={','.join(ev.axes)}" if ev.axes else ""
            lines.append(
                f"  sync {key}: {ev.sync_ops} op(s){exp}{axes} "
                f"dtypes={','.join(ev.wire_dtypes) or '-'} "
                f"payload={ev.payload_bytes}B/worker")
        for key, rnd in sorted(self.rounds.items()):
            extras = []
            if rnd.callbacks:
                extras.append(f"callbacks={len(rnd.callbacks)}")
            if rnd.transfers:
                extras.append(f"transfers={len(rnd.transfers)}")
            if rnd.jit_cache_size is not None:
                extras.append(f"traces={rnd.jit_cache_size}")
            lines.append(f"  round {key}: {rnd.collective_count} "
                         f"collective(s) {' '.join(extras)}".rstrip())
        if self.wire is not None:
            lines.append(f"  wire: {self.wire['payload_bytes']}B/worker "
                         f"declared, dtypes="
                         f"{','.join(self.wire['wire_dtypes'])}")
        if self.probes is not None:
            for key, d in sorted(self.probes.get("rounds", {}).items()):
                lines.append(
                    f"  probes {key}: +{d.get('extra_ops', 0)} op(s) vs "
                    f"metrics-off (budget {self.probes.get('budget', 0)}), "
                    f"+{d.get('extra_callbacks', 0)} callback(s), "
                    f"+{d.get('extra_transfers', 0)} transfer(s)")
        for f in self.findings:
            tag = "waived" if f.waived else "FINDING"
            why = f" [{f.waive_reason}]" if f.waived else ""
            lines.append(f"  {tag} {f.rule} {f.subject}: {f.message}{why}")
        if not self.findings:
            lines.append("  findings: none")
        return "\n".join(lines)
