"""``python -m repro.analysis`` — audit the reference configs and gate on
the committed budget.

The config matrix is small but deliberately spans every lowering path the
rules distinguish: sim and mesh executors, two- and three-level schedules,
comms off / identity / compressing (int8), a momentum run (optimizer
moments on the wire), the mesh ``exact=True`` replay, and metrics-on
``probes`` configs (the R6 overhead contract of the in-graph divergence
probe, on both backends).  Mesh configs need
one device per worker (8); on fewer devices they are skipped — their budget
entries survive ``--update`` untouched, which is how one budget file serves
both CI legs.

    python -m repro.analysis                 # print the audit summaries
    python -m repro.analysis --check         # diff vs ANALYSIS_budget.json
    python -m repro.analysis --update        # re-pin the budget (merge)
    python -m repro.analysis --out r.json    # dump the full reports as JSON
"""
from __future__ import annotations

import argparse
import json
import sys
from fnmatch import fnmatch
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.analysis import (BUDGET_FILE, audit_engine, check_reports,
                            load_budget, save_budget, update_budget,
                            waivers_for)

ROOT = Path(__file__).resolve().parents[3]

# one global period: two_level = (8 workers) 2 pods x 4, sync L2 every 4
# steps, L1 every 8; three_level adds an L3 sync every 2
_SPECS = {
    "two_level": ((2, 4), (8, 4)),
    "three_level": ((2, 2, 2), (8, 4, 2)),
}

# name -> (spec, executor, comms, optimizer, metrics)
CONFIGS = {
    "sim/two_level/off": ("two_level", "sim", None, "sgd", None),
    "sim/two_level/identity": ("two_level", "sim", "identity", "sgd", None),
    "sim/two_level/int8": ("two_level", "sim", "int8", "sgd", None),
    "sim/two_level/sign": ("two_level", "sim", "sign", "sgd", None),
    "sim/two_level/momentum-int8":
        ("two_level", "sim", "int8", "momentum", None),
    "sim/three_level/off": ("three_level", "sim", None, "sgd", None),
    "sim/three_level/int8": ("three_level", "sim", "int8", "sgd", None),
    "sim/two_level/probes": ("two_level", "sim", None, "sgd", "on"),
    "sim/three_level/probes": ("three_level", "sim", None, "sgd", "on"),
    "mesh/two_level/off": ("two_level", "mesh", None, "sgd", None),
    "mesh/two_level/identity": ("two_level", "mesh", "identity", "sgd", None),
    "mesh/two_level/int8": ("two_level", "mesh", "int8", "sgd", None),
    "mesh/two_level/sign": ("two_level", "mesh", "sign", "sgd", None),
    "mesh/two_level/exact-off": ("two_level", "mesh-exact", None, "sgd", None),
    "mesh/two_level/probes": ("two_level", "mesh", None, "sgd", "on"),
}


def build_engine(config: str):
    """(engine, state, batch_fn) for one matrix entry — a tiny MLP so the
    whole audit is tracing, not training."""
    from repro.core.executors import MeshExecutor
    from repro.core.hsgd import HSGD
    from repro.core.topology import HierarchySpec, make_topology
    from repro.models.simple import SimpleConfig, SimpleModel
    from repro.optim.optimizers import momentum, sgd

    spec_name, executor, comms, opt_name, metrics = CONFIGS[config]
    sizes, periods = _SPECS[spec_name]
    topo = make_topology("uniform", spec=HierarchySpec(sizes, periods))
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=16, hidden=8,
                                     num_classes=4))
    if executor == "mesh-exact":
        executor = MeshExecutor(exact=True)
    opt = momentum(0.1) if opt_name == "momentum" else sgd(0.1)
    eng = HSGD(model.loss, opt, topo, executor=executor, comms=comms,
               metrics=metrics)
    state = eng.init(jax.random.PRNGKey(0), model.init)
    n = topo.n

    def batch_fn(t):
        x = jax.random.normal(jax.random.PRNGKey(t), (n, 4, 16))
        y = jnp.zeros((n, 4), jnp.int32)
        return {"x": x, "y": y}

    return eng, state, batch_fn


def runnable(config: str) -> bool:
    if not config.startswith("mesh/"):
        return True
    sizes, _ = _SPECS[CONFIGS[config][0]]
    n = 1
    for s in sizes:
        n *= s
    return len(jax.devices()) >= n


def run_audits(budget, patterns):
    reports, skipped = [], []
    for config in CONFIGS:
        if patterns and not any(fnmatch(config, p) for p in patterns):
            continue
        if not runnable(config):
            skipped.append(config)
            continue
        eng, state, batch_fn = build_engine(config)
        reports.append(audit_engine(eng, state, batch_fn, config=config,
                                    waivers=waivers_for(budget, config)))
    return reports, skipped


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="collective audit of the reference engine configs")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) on any budget regression")
    ap.add_argument("--update", action="store_true",
                    help="re-pin the audited configs in the budget (merge)")
    ap.add_argument("--budget", default=str(ROOT / BUDGET_FILE),
                    help=f"budget path (default: repo-root {BUDGET_FILE})")
    ap.add_argument("--out", default=None,
                    help="also write the full SyncPlanReport JSON here")
    ap.add_argument("--configs", default="",
                    help="comma-separated fnmatch filters (default: all)")
    args = ap.parse_args(argv)

    budget = load_budget(args.budget)
    patterns = [p for p in args.configs.split(",") if p]
    reports, skipped = run_audits(budget, patterns)

    for report in reports:
        print(report.summary())
    if skipped:
        print(f"skipped (need more devices, budget entries kept): "
              f"{', '.join(skipped)}")

    if args.out:
        payload = {"device_count": len(jax.devices()),
                   "skipped": skipped,
                   "configs": {r.config: r.to_dict() for r in reports}}
        Path(args.out).write_text(json.dumps(payload, indent=2) + "\n",
                                  encoding="utf-8")
        print(f"wrote {args.out}")

    if args.update:
        save_budget(args.budget, update_budget(budget, reports))
        print(f"budget updated: {args.budget}")
        return 0

    regs, imps = check_reports(reports, budget)
    for msg in imps:
        print(f"IMPROVED  {msg}  (re-pin with --update)")
    for msg in regs:
        print(f"REGRESSED {msg}")
    if args.check and regs:
        print(f"collective audit: {len(regs)} regression(s)")
        return 1
    if args.check:
        print(f"collective audit: OK ({len(reports)} config(s), "
              f"{len(imps)} improvement note(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
