"""The lint rules over a :class:`~repro.analysis.report.SyncPlanReport`.

Each rule is a pure function ``rule(report) -> [Finding]`` operating on the
report's plain data — never on live jaxprs — so every rule is testable from
a hand-built report fixture.  The catalog (mirrored in DESIGN.md):

* **R1 sync-op count** — each event's lowered sync-op count must equal the
  schedule-derived expectation: ``buckets × encode-keys`` with comms on
  (O(dtypes)), ``leaves × encode-keys`` without (O(leaves)).  Skipped when
  no exact prediction exists (grouped topology, weighted aggregator,
  ``exact=True``) — those configs are pinned by the budget diff instead.
* **R2 no-f32-on-the-wire** — with a *compressing* codec active, float32
  must be a strict minority of what the lowered sync ops move:
  ``f32_elements > payload_elements // 2`` fires.  The compressed-
  allreduce lowering keeps the encoded payload on the collective (int8
  psums as a widened int32, sign votes as unpacked bits, top-k all-gathers
  its sparse (values, indices) payload), so only small scale statistics —
  and the f32 half of a top-k payload — may ride in f32.  The legacy
  encode→reduce(f32)→decode roundtrip (``Comms(wire_reduce=False)``)
  decodes BEFORE the reduction and still fires on every compressing
  config.  Reports predating the ``f32_elements`` field fall back to the
  original any-f32-dtype check.
* **R3 host-free round body** — no host callbacks (``debug_callback``,
  ``pure_callback``, ``io_callback``) or device transfers inside a traced
  round program: one round must stay one device program.
* **R4 retrace detection** — each Round signature compiles exactly once
  across ``run_rounds``: the executor's round cache returns a stable
  callable and the jit cache holds at most one variant per signature.
* **R5 wire-accounting cross-check** — the per-worker elements the lowered
  sync ops consume must equal the static ``WireStats`` element count:
  accounting (what history's ``wire_bytes`` reports) may not drift from
  reality (what the program moves).
* **R6 probe overhead** — a metrics-on round body must add ZERO host
  callbacks and zero device transfers versus its metrics-off twin
  (observability may never reintroduce the per-step host sync R3 banned),
  and at most ``Metrics.op_budget`` extra aggregation ops (the declared
  cost of the in-graph divergence probe + grad-norm channel).  Skipped on
  reports without a ``probes`` block (engine audited with metrics off).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Mapping

from repro.analysis.report import Finding, SyncPlanReport


def rule_r1_sync_op_count(report: SyncPlanReport) -> List[Finding]:
    out = []
    for key, ev in sorted(report.events.items()):
        if ev.expected_sync_ops is None:
            continue
        if ev.sync_ops != ev.expected_sync_ops:
            out.append(Finding(
                "R1", key,
                f"lowered sync has {ev.sync_ops} aggregation op(s), "
                f"schedule predicts {ev.expected_sync_ops}"))
    return out


def rule_r2_wire_dtypes(report: SyncPlanReport) -> List[Finding]:
    if report.codec in (None, "identity"):
        return []
    out = []
    for key, ev in sorted(report.events.items()):
        if ev.f32_elements is None:
            # report predates the element accounting: dtype-presence check
            if "float32" in ev.wire_dtypes:
                out.append(Finding(
                    "R2", key,
                    f"compressing codec '{report.codec}' is active but the "
                    f"lowered sync reduces float32 — the "
                    f"encode→reduce→decode path decodes BEFORE the "
                    f"reduction, so compression never reaches the wire"))
        elif ev.f32_elements > ev.payload_elements // 2:
            out.append(Finding(
                "R2", key,
                f"compressing codec '{report.codec}' is active but "
                f"{ev.f32_elements} of the {ev.payload_elements} "
                f"elements/worker the lowered sync moves are float32 — "
                f"the payload is decoded before it reaches the collective, "
                f"so the declared compression never reaches the wire"))
    return out


def rule_r3_host_free(report: SyncPlanReport) -> List[Finding]:
    out = []
    for key, rnd in sorted(report.rounds.items()):
        for kind, ops in (("host callback", rnd.callbacks),
                          ("device transfer", rnd.transfers)):
            for op in ops:
                out.append(Finding(
                    "R3", key, f"{kind} '{op}' inside the round body"))
    return out


def rule_r4_retrace(report: SyncPlanReport) -> List[Finding]:
    out = []
    for key, rnd in sorted(report.rounds.items()):
        if not rnd.cache_stable:
            out.append(Finding(
                "R4", key,
                "executor round cache returned a different callable for an "
                "equal Round signature"))
        if rnd.jit_cache_size is not None and rnd.jit_cache_size > 1:
            out.append(Finding(
                "R4", key,
                f"round signature traced {rnd.jit_cache_size} times across "
                f"run_rounds (expected once)"))
    return out


def rule_r5_wire_accounting(report: SyncPlanReport) -> List[Finding]:
    out = []
    for key, ev in sorted(report.events.items()):
        if ev.expected_payload_elements is None:
            continue
        if ev.payload_elements != ev.expected_payload_elements:
            out.append(Finding(
                "R5", key,
                f"lowered sync consumes {ev.payload_elements} elements/worker "
                f"but WireStats accounts {ev.expected_payload_elements} — "
                f"static accounting drifted from the lowered program"))
    return out


def rule_r6_probe_overhead(report: SyncPlanReport) -> List[Finding]:
    if report.probes is None:
        return []
    out = []
    budget = int(report.probes.get("budget", 0))
    for key, d in sorted(report.probes.get("rounds", {}).items()):
        cbs = int(d.get("extra_callbacks", 0))
        xfs = int(d.get("extra_transfers", 0))
        if cbs > 0 or xfs > 0:
            out.append(Finding(
                "R6", key,
                f"metrics-on round body adds {cbs} host callback(s) and "
                f"{xfs} device transfer(s) vs its metrics-off twin — the "
                f"probe must stay in-graph (drained in bulk, never per "
                f"round)"))
        extra = int(d.get("extra_ops", 0))
        if extra > budget:
            out.append(Finding(
                "R6", key,
                f"metrics-on round body adds {extra} aggregation op(s) vs "
                f"its metrics-off twin, over the declared probe budget of "
                f"{budget}"))
    return out


RULES: Dict[str, Callable[[SyncPlanReport], List[Finding]]] = {
    "R1": rule_r1_sync_op_count,
    "R2": rule_r2_wire_dtypes,
    "R3": rule_r3_host_free,
    "R4": rule_r4_retrace,
    "R5": rule_r5_wire_accounting,
    "R6": rule_r6_probe_overhead,
}


def run_rules(report: SyncPlanReport,
              waivers: Mapping[str, str] = ()) -> List[Finding]:
    """Run every rule; mark findings whose rule id appears in ``waivers``
    (``{rule_id: reason}``) as waived rather than dropping them — a waived
    finding stays visible in the report and the budget, it just does not
    fail a check."""
    waivers = dict(waivers or {})
    findings: List[Finding] = []
    for rule_id, rule in RULES.items():
        for f in rule(report):
            if rule_id in waivers:
                f = Finding(f.rule, f.subject, f.message, waived=True,
                            waive_reason=waivers[rule_id])
            findings.append(f)
    return findings
