"""repro.analysis — the collective auditor & sync-plan linter.

A static pass over the engine's LOWERED programs: the walker
(:mod:`.walker`) turns any jaxpr into plain op records; the engine
(:mod:`.engine`) traces a live :class:`~repro.core.hsgd.HSGD` into a
:class:`~repro.analysis.report.SyncPlanReport`; the rules (:mod:`.rules`)
lint the report (R1 sync-op count, R2 wire-dtype honesty, R3 host-free
round body, R4 retrace detection, R5 wire-accounting cross-check); the
budget (:mod:`.budget`) diffs reports against the committed
``ANALYSIS_budget.json`` so CI fails on new collectives, dtype upcasts or
byte growth.  Entry points: ``eng.audit(state, batch_fn)`` and
``python -m repro.analysis --check`` (see README.md "Static analysis" and
DESIGN.md "Analysis layer").
"""
from repro.analysis.budget import (BUDGET_FILE, check_reports, diff_entry,
                                   entry_from_report, load_budget,
                                   save_budget, update_budget, waivers_for)
from repro.analysis.engine import audit_engine, event_key, round_key
from repro.analysis.report import (EventAudit, Finding, RoundAudit,
                                   SyncPlanReport)
from repro.analysis.rules import RULES, run_rules
from repro.analysis.walker import (CALLBACK_PRIMS, COLLECTIVE_PRIMS,
                                   REDUCE_PRIMS, TRANSFER_PRIMS, JaxprSummary,
                                   OpRecord, fingerprint, trace, walk)

__all__ = [
    "walk", "trace", "fingerprint", "JaxprSummary", "OpRecord",
    "COLLECTIVE_PRIMS", "CALLBACK_PRIMS", "TRANSFER_PRIMS", "REDUCE_PRIMS",
    "EventAudit", "RoundAudit", "Finding", "SyncPlanReport",
    "RULES", "run_rules",
    "audit_engine", "event_key", "round_key",
    "BUDGET_FILE", "load_budget", "save_budget", "waivers_for",
    "entry_from_report", "diff_entry", "check_reports", "update_budget",
]
