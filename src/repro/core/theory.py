"""Numerical evaluation of every convergence bound in the paper.

Theorem 1 (two-level, fixed grouping), Corollary 1 (local SGD), Theorem 2
(random grouping), Theorem 3 (multi-level, random grouping), Lemmas 1-3, the
sandwich inequalities (16)(17)(23)(24), and the Table-1 comparison rows
(Yu et al. 2019, Liu et al. 2020, Castiglia et al. 2021).

Everything returns plain floats so the benchmark harness can emit Table 1 and
property tests can assert the algebra (recovery when N=1, sandwich, ...).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

C_CONST = 40.0 / 3.0  # the paper's C


# ---------------------------------------------------------------------------
# Theorem 1: two-level, fixed (possibly non-uniform) grouping
# ---------------------------------------------------------------------------
def theorem1_bound(*, gamma: float, T: int, L: float, sigma2: float,
                   f0_minus_fstar: float, n: int, G: int,
                   group_sizes: Sequence[int], I_periods: Sequence[int],
                   eps_up2: float, eps_down2: Sequence[float]) -> float:
    """Eq. (11a)-(11c). Requires gamma < 1/(2 sqrt(6) G L)."""
    N = len(group_sizes)
    assert len(I_periods) == N and len(eps_down2) == N
    assert sum(group_sizes) == n
    c = C_CONST
    t11a = 2.0 * f0_minus_fstar / (gamma * T) + gamma * L * sigma2 / n
    t11b = (2.0 * c * gamma**2 * L**2 * G * (N - 1) / n * sigma2
            + 3.0 * c * gamma**2 * L**2 * G**2 * eps_up2)
    t11c = 0.0
    for ni, Ii, ei2 in zip(group_sizes, I_periods, eps_down2):
        t11c += 2.0 * c * gamma**2 * L**2 * sigma2 * (ni - 1) * Ii / n
        t11c += 3.0 * c * gamma**2 * L**2 * (ni / n) * Ii**2 * ei2
    return t11a + t11b + t11c


def corollary1_local_sgd_bound(*, gamma: float, T: int, L: float, sigma2: float,
                               f0_minus_fstar: float, n: int, P: int,
                               eps_tilde2: float) -> float:
    """Eq. (12): Theorem 1 with N=1 (single group of size n, I_1 = P = G)."""
    return theorem1_bound(
        gamma=gamma, T=T, L=L, sigma2=sigma2, f0_minus_fstar=f0_minus_fstar,
        n=n, G=P, group_sizes=[n], I_periods=[P],
        eps_up2=0.0, eps_down2=[eps_tilde2])


def lr_cap(G: int, L: float) -> float:
    return 1.0 / (2.0 * math.sqrt(6.0) * G * L)


# ---------------------------------------------------------------------------
# Lemmas 1 & 2 (random grouping divergence expectations)
# ---------------------------------------------------------------------------
def lemma1_rhs(n: int, N: int, eps_tilde2: float) -> float:
    return (N - 1) / (n - 1) * eps_tilde2


def lemma2_rhs(n: int, N: int, eps_tilde2: float) -> float:
    return (1.0 - (N - 1) / (n - 1)) * eps_tilde2


# ---------------------------------------------------------------------------
# Theorem 2: two-level random grouping (equal group sizes, common I)
# ---------------------------------------------------------------------------
def theorem2_bound(*, gamma: float, T: int, L: float, sigma2: float,
                   f0_minus_fstar: float, n: int, N: int, G: int, I: int,
                   eps_tilde2: float) -> float:
    c = C_CONST
    base = 2.0 * f0_minus_fstar / (gamma * T) + gamma * L * sigma2 / n
    noise = 2.0 * c * gamma**2 * L**2 * (
        (N - 1) / n * G + (1.0 - N / n) * I) * sigma2
    div = 3.0 * c * gamma**2 * L**2 * (
        (N - 1) / (n - 1) * G**2 + (1.0 - (N - 1) / (n - 1)) * I**2) * eps_tilde2
    return base + noise + div


def sandwich_noise_terms(n: int, N: int, G: int, I: int):
    """Eq. (16): ((1-1/n) I, middle, (1-1/n) G)."""
    mid = (N - 1) / n * G + (1.0 - N / n) * I
    return ((1.0 - 1.0 / n) * I, mid, (1.0 - 1.0 / n) * G)


def sandwich_div_terms(n: int, N: int, G: int, I: int):
    """Eq. (17): (I^2, middle, G^2)."""
    mid = (N - 1) / (n - 1) * G**2 + (1.0 - (N - 1) / (n - 1)) * I**2
    return (float(I**2), mid, float(G**2))


def remark5_ok(n: int, N: int, G: int, I: int, l: float, q: float) -> bool:
    """Remark 5 feasibility: G'=lG, I'=qI improves the bound's div terms."""
    m = G // I
    lmax = math.sqrt((1.0 / m**2) * (n - N) / N + 1.0)
    if not (1.0 < l < lmax):
        return False
    qmax = math.sqrt(max(0.0, 1.0 - m**2 * (l**2 - 1.0) * N / (n - N)))
    return q <= qmax


# ---------------------------------------------------------------------------
# Theorem 3: multi-level random grouping
# ---------------------------------------------------------------------------
def theorem3_A1(level: int, periods: Sequence[int],
                group_sizes: Sequence[int]) -> float:
    """A_1(l) = P_1 (1/prod_{j>l} N_j - 1/n) + P_{l+1} (1 - 1/prod_{j>l} N_j).

    NOTE on indexing: the paper prints prod_{j=l}^M and P_l, but that reading
    does NOT reduce to Theorem 2 at M=2 (it gives the sandwich's upper
    extreme (1-1/n)P_1 instead of the Theorem-2 middle term), contradicting
    Remark 6.  The reading with prod_{j=l+1}^M and P_{l+1} reduces exactly to
    Theorem 2 and satisfies (23)-(24); we implement that and record the
    erratum in DESIGN.md.
    """
    n = int(np.prod(group_sizes))
    prod_gt = int(np.prod(group_sizes[level:]))      # prod_{j=l+1..M} N_j
    return (periods[0] * (1.0 / prod_gt - 1.0 / n)
            + periods[level] * (1.0 - 1.0 / prod_gt))


def theorem3_A2(level: int, periods: Sequence[int],
                group_sizes: Sequence[int]) -> float:
    """A_2(l) = P_1^2 (n_l-1)/(n-1) + P_{l+1}^2 (1 - (n_l-1)/(n-1)).
    Same indexing erratum as A_1 (see theorem3_A1)."""
    n = int(np.prod(group_sizes))
    n_l = int(np.prod(group_sizes[:level]))          # n_l = prod_{j<=l} N_j
    frac = (n_l - 1) / (n - 1)
    return periods[0] ** 2 * frac + periods[level] ** 2 * (1.0 - frac)


def theorem3_bound(*, gamma: float, T: int, L: float, sigma2: float,
                   f0_minus_fstar: float, periods: Sequence[int],
                   group_sizes: Sequence[int], eps_tilde2: float) -> float:
    """Eq. (22). periods=(P_1..P_M), group_sizes=(N_1..N_M)."""
    M = len(group_sizes)
    assert len(periods) == M and M >= 2
    n = int(np.prod(group_sizes))
    c = C_CONST
    base = 2.0 * f0_minus_fstar / (gamma * T) + gamma * L * sigma2 / n
    acc = 0.0
    for lvl in range(1, M):
        a1 = theorem3_A1(lvl, periods, group_sizes)
        a2 = theorem3_A2(lvl, periods, group_sizes)
        acc += 2.0 * a1 * sigma2 + 3.0 * a2 * eps_tilde2
    return base + c * gamma**2 * L**2 * acc / (M - 1)


# ---------------------------------------------------------------------------
# Table 1 comparison rows (O-expressions evaluated with unit constants)
# ---------------------------------------------------------------------------
def table1_yu2019(n, T, P, sigma2, eps_tilde2):
    """Yu, Jin, Yang 2019 (local SGD): O((1+s^2)/sqrt(nT) + n/T (P s^2 + P^2 e^2))."""
    return (1 + sigma2) / math.sqrt(n * T) + n / T * (P * sigma2 + P**2 * eps_tilde2)


def table1_liu2020(n, T, G, eps_tilde2, B=2.5):
    """Liu et al. 2020 (full-batch H-SGD): O((1 + B^G e^2)/sqrt(nT)), B>2."""
    return (1 + B**G * eps_tilde2) / math.sqrt(n * T)


def table1_castiglia2021(n, T, G, I, sigma2):
    """Castiglia et al. 2021 (IID H-SGD): O((1+s^2)/sqrt(nT) + n/T G^2/I s^2)."""
    return (1 + sigma2) / math.sqrt(n * T) + n / T * (G**2 / I) * sigma2


def table1_ours(n, N, T, G, I, sigma2, eps_tilde2):
    """Our row: O((1+s^2)/sqrt(nT)
                 + ((N-1)(G s^2 + G^2 e^2) + (n-N)(I s^2 + I^2 e^2)) / T)."""
    return ((1 + sigma2) / math.sqrt(n * T)
            + ((N - 1) * (G * sigma2 + G**2 * eps_tilde2)
               + (n - N) * (I * sigma2 + I**2 * eps_tilde2)) / T)
