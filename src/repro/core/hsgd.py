"""The H-SGD engine (paper Algorithm 1 and multi-level Algorithm D.1).

State layout: every worker owns a full model replica; ``params`` and
``opt_state`` carry a leading worker axis of size n.  One engine serves both
execution modes:

* sim  — n = tens..hundreds of CPU "workers"; used for the paper-experiment
  reproduction.  Aggregations are reshapes/means (uniform hierarchy) or
  membership segment-means (arbitrary fixed groupings, Theorem 1).
* mesh — n = product of replica mesh axes; the SAME code, but params are
  sharded ``P(('pod','data'), ...)`` so the level-ℓ mean lowers to an
  all-reduce over exactly the mesh axes of levels >= ℓ (local sync = intra-pod
  ICI; global sync additionally crosses the pod axis).

Which workers average when — and by what rule — lives entirely in the
:class:`~repro.core.topology.Topology` / ``Aggregator`` layer; the engine
only dispatches on typed :class:`~repro.core.topology.SyncEvent`s.  Because
the periods are static, each distinct event is its own jitted function — no
lax.cond around collectives, so the lowered HLO per step kind is exact (the
roofline reads it).  ``run_rounds`` goes further: it compiles the event
schedule into rounds and fuses each pure-local block into a single jitted
``lax.scan``, removing the per-step Python dispatch entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.topology import SyncEvent, Topology
from repro.optim.optimizers import Optimizer


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HSGDState:
    params: Any      # leading worker axis n
    opt_state: Any   # leading worker axis n
    step: jax.Array  # scalar int32


# ---------------------------------------------------------------------------
# schedule compilation (for run_rounds)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Round:
    """``n_local`` local updates, the last one followed by ``event`` (None
    only for a schedule tail that ends between syncs)."""
    n_local: int
    event: Optional[SyncEvent]


def compile_schedule(schedule) -> Tuple[Round, ...]:
    """Fold a per-step event schedule into maximal pure-local rounds."""
    rounds: List[Round] = []
    k = 0
    for ev in schedule:
        k += 1
        if ev is not None:
            rounds.append(Round(k, ev))
            k = 0
    if k:
        rounds.append(Round(k, None))
    return tuple(rounds)


class HSGD:
    """loss_fn(params, batch) -> (loss, metrics-dict). Batch passed to
    ``step`` must carry a leading worker axis of size n."""

    def __init__(self, loss_fn: Callable, optimizer: Optimizer,
                 topology: Topology, *, aggregate_opt_state: bool = True,
                 jit: bool = True, accum_steps: int = 1):
        """accum_steps > 1: each H-SGD iteration accumulates gradients over
        that many microbatches (scan) before the single optimizer update —
        same semantics as one large-batch step (SGD is linear in the
        gradient; tested), peak activation memory divided by accum_steps."""
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.topology = topology
        self.aggregate_opt_state = aggregate_opt_state
        self._jit = jit
        self.accum_steps = accum_steps
        self._step_fns: Dict[Any, Callable] = {}
        self._round_fns: Dict[Round, Callable] = {}

    # -- init ---------------------------------------------------------------
    def init(self, key, model_init: Callable[[jax.Array], Any]) -> HSGDState:
        """All workers start from the SAME w̄^0 (paper input)."""
        params0 = model_init(key)
        n = self.topology.n
        params = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params0)
        opt0 = self.optimizer.init(params0)
        opt_state = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), opt0)
        return HSGDState(params, opt_state, jnp.zeros((), jnp.int32))

    # -- building blocks ------------------------------------------------------
    def _local_update(self):
        """(params, opt_state, batch) -> (params, opt_state, metrics) for ONE
        worker; vmapped over the worker axis by the step/round builders."""
        grad_fn = jax.grad(lambda p, b: self.loss_fn(p, b), has_aux=True)
        accum = self.accum_steps

        def mean_grads(params, batch):
            if accum == 1:
                return grad_fn(params, batch)

            def micro(acc, mb):
                g, m = grad_fn(params, mb)
                return jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g), m

            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            gsum, ms = jax.lax.scan(micro, zero, mbs)
            grads = jax.tree.map(
                lambda g, p: (g / accum).astype(p.dtype), gsum, params)
            return grads, jax.tree.map(lambda m: m.mean(0), ms)

        def local_update(params, opt_state, batch):
            grads, metrics = mean_grads(params, batch)
            updates, opt_state = self.optimizer.update(grads, opt_state, params)
            params = jax.tree.map(jnp.add, params, updates)
            return params, opt_state, metrics

        return local_update

    def _apply_event(self, params, opt_state, event: SyncEvent, mask=None):
        params = self.topology.aggregate(params, event, mask=mask)
        if self.aggregate_opt_state:
            # average optimizer moments with the same schedule as the
            # params (paper's SGD has none; momentum/adam extension)
            agg = self.topology.aggregate(_moments_only(opt_state), event,
                                          mask=mask)
            opt_state = _merge_moments(opt_state, agg)
        return params, opt_state

    # -- one combined step per event ------------------------------------------
    def _build_step(self, event: Optional[SyncEvent], masked: bool = False):
        local_update = self._local_update()

        def apply_mask(new, old, mask):
            """Non-participating workers keep their previous state."""
            def sel(a, b):
                m = mask.reshape((-1,) + (1,) * (a.ndim - 1))
                return jnp.where(m, a, b)
            return jax.tree.map(sel, new, old)

        def step(state: HSGDState, batch, mask=None) -> Tuple[HSGDState, Dict]:
            params, opt_state, metrics = jax.vmap(local_update)(
                state.params, state.opt_state, batch)
            if masked:
                params = apply_mask(params, state.params, mask)
                opt_state = apply_mask(opt_state, state.opt_state, mask)
            if event is not None:
                amask = mask if masked else None
                params, opt_state = self._apply_event(params, opt_state,
                                                      event, mask=amask)
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
            return HSGDState(params, opt_state, state.step + 1), metrics

        if not self._jit:
            return step
        return jax.jit(step, donate_argnums=0) if masked else \
            jax.jit(lambda s, b: step(s, b), donate_argnums=0)

    def step_fn(self, event: Optional[SyncEvent], masked: bool = False):
        key = (event, masked)
        if key not in self._step_fns:
            self._step_fns[key] = self._build_step(event, masked)
        return self._step_fns[key]

    def step(self, state: HSGDState, batch,
             mask=None) -> Tuple[HSGDState, Dict]:
        """mask: optional (n,) bool — partial worker participation (held
        fixed by the caller within a round, re-drawn per round)."""
        event = self.topology.event_at(int(state.step))
        if mask is None:
            return self.step_fn(event)(state, batch)
        return self.step_fn(event, masked=True)(state, batch, jnp.asarray(mask))

    # -- schedule-compiled round executor --------------------------------------
    def _build_round(self, rnd: Round):
        """One jitted function for '``n_local`` local steps then sync': the
        local block is a single ``lax.scan`` over the stacked batches, so the
        whole round is ONE dispatch + ONE jit-cache hit instead of
        ``n_local`` of each."""
        local_update = self._local_update()
        vupdate = jax.vmap(local_update)

        def round_fn(state: HSGDState, batches) -> Tuple[HSGDState, Dict]:
            """batches: a length-``n_local`` tuple of per-step batches; the
            stacking happens INSIDE the jitted graph so one round is exactly
            one dispatch (no host-side jnp.stack per round)."""
            stacked = batches[0] if rnd.n_local == 1 else \
                jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
            if rnd.n_local == 1:
                stacked = jax.tree.map(lambda x: x[None], stacked)

            def body(carry, batch):
                params, opt_state = carry
                params, opt_state, metrics = vupdate(params, opt_state, batch)
                return (params, opt_state), jax.tree.map(
                    lambda m: m.mean(), metrics)

            (params, opt_state), metrics = jax.lax.scan(
                body, (state.params, state.opt_state), stacked)
            if rnd.event is not None:
                params, opt_state = self._apply_event(params, opt_state,
                                                      rnd.event)
            state = HSGDState(params, opt_state, state.step + rnd.n_local)
            return state, metrics  # metrics stacked (n_local,) per entry

        if not self._jit:
            return round_fn
        return jax.jit(round_fn, donate_argnums=0)

    def round_fn(self, rnd: Round):
        if rnd not in self._round_fns:
            self._round_fns[rnd] = self._build_round(rnd)
        return self._round_fns[rnd]

    def run_rounds(self, state: HSGDState, batch_fn: Callable[[int], Any],
                   T: int, *, eval_every: int = 0,
                   eval_fn: Optional[Callable[[HSGDState, int], Dict]] = None,
                   ) -> Tuple[HSGDState, List[Dict]]:
        """Run T steps through the schedule-compiled executor.

        Precomputes ``topology.schedule(T)``, folds it into rounds
        (``compile_schedule``) and executes each as one fused call.  The
        trajectory is identical to T calls of :meth:`step` (tested);
        distinct ``Round`` signatures are compiled once and reused.

        History records per-step training metrics for EVERY step; when
        ``eval_every`` is set, ``eval_fn(state, t)`` results are merged into
        the record at round boundaries where ``(t+1) % eval_every == 0`` (or
        at t+1 == T) — within a round the intermediate states never
        materialize, which is where the speed comes from."""
        t0 = int(state.step)
        rounds = compile_schedule(self.topology.schedule(t0 + T)[t0:])
        raw: List[Tuple[int, int, Dict]] = []  # (t_end, n_local, metrics)
        evals: Dict[int, Dict] = {}
        t = t0
        for rnd in rounds:
            batches = tuple(batch_fn(t + i) for i in range(rnd.n_local))
            state, metrics = self.round_fn(rnd)(state, batches)
            t += rnd.n_local
            raw.append((t, rnd.n_local, metrics))
            if eval_fn is not None and eval_every and \
                    (t % eval_every == 0 or t == t0 + T):
                evals[t] = eval_fn(state, t - 1)
        # metrics stay on device until here so rounds dispatch back-to-back;
        # one bulk transfer at the end instead of a sync per step
        history: List[Dict] = []
        for t_end, n_local, metrics in raw:
            metrics = jax.device_get(metrics)
            for i in range(n_local):
                step_no = t_end - n_local + i + 1
                rec = {"t": step_no,
                       **{k: float(v[i]) for k, v in metrics.items()}}
                rec.update(evals.get(step_no, {}))
                history.append(rec)
        return state, history

    # -- inspection ------------------------------------------------------------
    def mean_params(self, state: HSGDState):
        """w̄^t (the analysis object; observable only at t = aG)."""
        return jax.tree.map(
            lambda x: x.mean(0, dtype=jnp.float32).astype(x.dtype), state.params)

    def worker_params(self, state: HSGDState, j: int):
        return jax.tree.map(lambda x: x[j], state.params)


def _moments_only(opt_state):
    return {k: v for k, v in opt_state.items() if k in ("m", "v")}


def _merge_moments(opt_state, agg):
    out = dict(opt_state)
    out.update(agg)
    return out


# ---------------------------------------------------------------------------
# convenience: run T steps with a data source
# ---------------------------------------------------------------------------
def run(engine: HSGD, state: HSGDState, batch_fn: Callable[[int], Any],
        T: int, eval_every: int = 0,
        eval_fn: Optional[Callable[[HSGDState, int], Dict]] = None):
    """batch_fn(t) -> batch with leading worker axis. Returns (state, history).

    History gets one record per step with the training metrics (previously it
    was silently empty unless ``eval_every`` was set); ``eval_fn`` results are
    merged into the matching step's record every ``eval_every`` steps."""
    history = []
    for t in range(T):
        state, metrics = engine.step(state, batch_fn(t))
        rec = {"t": t + 1, **{k: float(v) for k, v in metrics.items()}}
        if eval_every and (t + 1) % eval_every == 0 and eval_fn is not None:
            rec.update(eval_fn(state, t))
        history.append(rec)
    return state, history
