"""The H-SGD engine (paper Algorithm 1 and multi-level Algorithm D.1).

The engine is split into two layers:

* **plan layer** (this module) — everything backend-agnostic: schedule
  compilation (``compile_schedule`` folds the event schedule into ``Round``s),
  gradient accumulation, history/eval bookkeeping, and the typed-event
  dispatch.  ``HSGD`` owns the plan and never touches devices directly.
* **executor layer** (:mod:`repro.core.executors`) — how a round body runs on
  hardware.  ``SimExecutor`` (default) vmaps over a leading worker axis on
  one device and aggregates with in-array segment means; ``MeshExecutor``
  runs the same round body under ``shard_map`` on a device mesh whose replica
  axes mirror the hierarchy levels, so each ``SyncEvent(level=ℓ)`` lowers to
  a ``lax.pmean`` over exactly the mesh axes of levels >= ℓ (local sync =
  fast intra-pod ICI; global sync additionally crosses the slow pod axis).

State layout: every worker owns a full model replica; ``params`` and
``opt_state`` carry a leading worker axis of size n (sharded over the replica
mesh axes under the mesh executor, a plain array dimension under sim).

Which workers average when — and by what rule — lives entirely in the
:class:`~repro.core.topology.Topology` / ``Aggregator`` layer; the engine
only dispatches on typed :class:`~repro.core.topology.SyncEvent`s.  Because
the periods are static, each distinct event is its own jitted function — no
lax.cond around collectives, so the lowered HLO per step kind is exact (the
roofline reads it).  ``run_rounds`` goes further: it compiles the event
schedule into rounds and fuses each pure-local block into a single jitted
``lax.scan``, removing the per-step Python dispatch entirely.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.topology import SyncEvent, Topology
from repro.optim.optimizers import Optimizer


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Typed engine configuration: every pluggable subsystem in one frozen
    object instead of the kwarg sprawl ``HSGD(..., comms=..., runtime=...,
    metrics=..., executor=...)`` grew across PRs 2–8.

    Each subsystem field takes whatever its ``make_*`` factory accepts —
    None, a registered name, or an instance: ``executor``
    (:func:`repro.core.executors.make_executor`), ``comms``
    (:func:`repro.comms.make_comms`), ``runtime``
    (:func:`repro.runtime.make_runtime`), ``metrics``
    (:func:`repro.obs.make_metrics`), ``population``
    (:func:`repro.population.make_population` — binding one switches the
    engine into the sampled-participation regime, see
    :meth:`HSGD.run_sampled`).  The scalar engine options
    (``aggregate_opt_state`` / ``jit`` / ``accum_steps``) live here too so
    one object round-trips a full engine setup (the train CLI echoes it
    into the JSONL header).

    The legacy keywords still work via a deprecation shim (tested), so
    ``HSGD(loss, opt, topo, comms="topk")`` and
    ``HSGD(loss, opt, topo, EngineConfig(comms="topk"))`` build the same
    engine.
    """
    executor: Any = None
    comms: Any = None
    runtime: Any = None
    metrics: Any = None
    population: Any = None
    aggregate_opt_state: bool = True
    jit: bool = True
    accum_steps: int = 1

    def describe(self) -> Dict[str, Any]:
        """JSON-able summary (the train CLI's JSONL ``config`` line)."""
        def show(v):
            if v is None or isinstance(v, (str, int, float, bool)):
                return v
            d = getattr(v, "describe", None)
            return d() if callable(d) else repr(v)
        return {f.name: show(getattr(self, f.name))
                for f in dataclasses.fields(self)}


_UNSET = object()
# kwargs the shim still accepts; the subsystem ones warn, the scalar ones
# (plain engine options, no sprawl history) fold in silently
_SUBSYSTEM_KWARGS = ("executor", "comms", "runtime", "metrics", "population")
_SCALAR_KWARGS = ("aggregate_opt_state", "jit", "accum_steps")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HSGDState:
    params: Any      # leading worker axis n
    opt_state: Any   # leading worker axis n
    step: jax.Array  # scalar int32
    comms: Any = None  # codec state (error-feedback residuals), worker axis n
    metrics: Any = None  # on-device probe buffer (repro.obs.MetricBuffer),
    #   replicated — None (default) contributes no leaves, so the lowered
    #   programs are identical to the pre-observability engine


# ---------------------------------------------------------------------------
# schedule compilation (for run_rounds)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Round:
    """``n_local`` local updates, the last one followed by ``event`` (None
    for a round that ends between syncs — a schedule tail, or a cut forced
    by ``cut_every``)."""
    n_local: int
    event: Optional[SyncEvent]


def compile_schedule(schedule, cut_every: int = 0,
                     t0: int = 0) -> Tuple[Round, ...]:
    """Fold a per-step event schedule into maximal pure-local rounds.

    ``cut_every`` additionally ends a round at every absolute step that is a
    multiple of it (``t0`` = absolute step of ``schedule[0]``) even without a
    sync event, so ``run_rounds`` eval points always land on a round boundary
    regardless of how they align with the sync periods."""
    rounds: List[Round] = []
    k = 0
    for i, ev in enumerate(schedule):
        k += 1
        if ev is not None or (cut_every and (t0 + i + 1) % cut_every == 0):
            rounds.append(Round(k, ev))
            k = 0
    if k:
        rounds.append(Round(k, None))
    return tuple(rounds)


class HSGD:
    """The plan layer.  loss_fn(params, batch) -> (loss, metrics-dict).
    Batch passed to ``step`` must carry a leading worker axis of size n.

    ``executor`` picks the execution backend: ``"sim"`` (default; vmap on one
    device), ``"mesh"`` (shard_map over a hierarchy-shaped device mesh), an
    :class:`~repro.core.executors.Executor` instance, or a registered name.

    ``comms`` selects the communication plan (:func:`repro.comms.make_comms`):
    None (default) keeps the leaf-wise aggregation path bitwise-identical to
    before; a codec name ("identity" | "int8" | "sign" | "topk") or a
    :class:`~repro.comms.Comms` routes every sync through fused flat-buffer
    payloads + that wire codec, and turns on per-level wire accounting
    (:meth:`wire_stats`; :meth:`run_rounds` history records additionally
    carry ``wire_bytes`` — the per-step :meth:`step` path does not).

    ``runtime`` selects the simulated-time model
    (:func:`repro.runtime.make_runtime`): None (default) is bitwise-identical
    to no runtime at all; a :class:`~repro.runtime.RuntimeModel` threads an
    event-driven :class:`~repro.runtime.SimClock` through :meth:`run_rounds`
    (per-worker straggler clocks, per-level link costs priced by the comms
    payload bytes), adds ``sim_time_s``/``sim_sync_s`` to every history
    record, and — with an elastic policy — converts missed sync deadlines
    into runtime-mask drops on either executor (the mesh backend lowers the
    mask as a per-worker collective weight; the per-step :meth:`step` path
    ignores the runtime, pass masks there yourself).

    ``metrics`` selects the observability plan
    (:func:`repro.obs.make_metrics`): None (default) is bitwise-identical
    to no observability at all — no buffer in the state, no probe in the
    round body, same lowered jaxpr; ``"on"`` / a :class:`~repro.obs.Metrics`
    carries an on-device :class:`~repro.obs.MetricBuffer` in the state and
    pushes the per-level parameter divergences (paper eq. (10): global =
    upward + downward) at EVERY sync event inside the jitted round body,
    plus a per-step ``grad_norm`` channel; :meth:`run_rounds` drains the
    buffer in one device→host transfer at eval boundaries and merges the
    values into history as ``div_*`` keys (the per-step :meth:`step` path
    pushes too — drain with :meth:`drain_metrics`).
    """

    def __init__(self, loss_fn: Callable, optimizer: Optimizer,
                 topology: Topology, config: Optional[EngineConfig] = None,
                 *, aggregate_opt_state=_UNSET, jit=_UNSET,
                 accum_steps=_UNSET, executor=_UNSET, comms=_UNSET,
                 runtime=_UNSET, metrics=_UNSET, population=_UNSET):
        """Subsystems come from ``config`` (an :class:`EngineConfig`); the
        pre-config keywords still work through a deprecation shim but may
        not be mixed with ``config``.

        accum_steps > 1: each H-SGD iteration accumulates gradients over
        that many microbatches (scan) before the single optimizer update —
        same semantics as one large-batch step (SGD is linear in the
        gradient; tested), peak activation memory divided by accum_steps."""
        overrides = {k: v for k, v in [
            ("aggregate_opt_state", aggregate_opt_state), ("jit", jit),
            ("accum_steps", accum_steps), ("executor", executor),
            ("comms", comms), ("runtime", runtime), ("metrics", metrics),
            ("population", population)] if v is not _UNSET}
        if overrides and config is not None:
            raise TypeError(
                f"HSGD got both config= and the keyword(s) "
                f"{sorted(overrides)}; move them into "
                f"EngineConfig({', '.join(sorted(overrides))}=...)")
        if config is None:
            legacy = sorted(k for k in overrides if k in _SUBSYSTEM_KWARGS)
            if legacy:
                warnings.warn(
                    f"HSGD({', '.join(k + '=...' for k in legacy)}) keyword"
                    f"{'s are' if len(legacy) > 1 else ' is'} deprecated; "
                    f"pass HSGD(loss_fn, optimizer, topology, "
                    f"EngineConfig({', '.join(k + '=...' for k in legacy)}))",
                    DeprecationWarning, stacklevel=2)
            config = EngineConfig(**overrides)
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.topology = topology
        self.config = config
        self.aggregate_opt_state = config.aggregate_opt_state
        self._jit = config.jit
        self.accum_steps = config.accum_steps
        # local imports: executors imports this module for HSGDState/Round,
        # and comms/runtime reach back into core.topology
        from repro.comms import make_comms
        self.comms = make_comms(config.comms)
        from repro.runtime import make_runtime
        self.runtime = make_runtime(config.runtime)
        from repro.obs import make_metrics
        self.metrics = make_metrics(config.metrics)
        from repro.population import make_population
        self.population = make_population(config.population)
        self._population_engine = None
        self._last_clock = None
        from repro.core.executors import make_executor
        self.executor = make_executor(config.executor)
        self.executor.bind(self)

    # -- participation (one protocol over the grown surfaces) ---------------
    def participation(self, clock=None, extra=None):
        """This engine's composed :class:`~repro.population.Participation`
        view: the topology's static event masks, plus the elastic adapter
        when a live clock is passed, plus ``extra`` (e.g. the population
        engine's per-round pinned sampler)."""
        from repro.population import (ElasticParticipation,
                                      StaticParticipation, compose)
        return compose(StaticParticipation(self.topology), extra,
                       ElasticParticipation(clock)
                       if clock is not None else None)

    # -- init ---------------------------------------------------------------
    def init(self, key, model_init: Callable[[jax.Array], Any]) -> HSGDState:
        """All workers start from the SAME w̄^0 (paper input)."""
        params0 = model_init(key)
        n = self.topology.n
        params = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params0)
        opt0 = self.optimizer.init(params0)
        opt_state = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), opt0)
        cstate = self.comms.init_state(params) if self.comms else None
        mbuf = self.metrics.init_buffer(self.topology) if self.metrics \
            else None
        state = HSGDState(params, opt_state, jnp.zeros((), jnp.int32), cstate,
                          mbuf)
        return self.executor.place(state)

    # -- building blocks ------------------------------------------------------
    def local_update_fn(self):
        """(params, opt_state, batch) -> (params, opt_state, metrics) for ONE
        worker — the pure per-worker half of the plan (with gradient
        accumulation folded in); executors map it over the worker axis
        (vmap under sim, one worker per mesh replica under mesh)."""
        grad_fn = jax.grad(lambda p, b: self.loss_fn(p, b), has_aux=True)
        accum = self.accum_steps

        def mean_grads(params, batch):
            if accum == 1:
                return grad_fn(params, batch)

            def micro(acc, mb):
                g, m = grad_fn(params, mb)
                return jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g), m

            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            gsum, ms = jax.lax.scan(micro, zero, mbs)
            grads = jax.tree.map(
                lambda g, p: (g / accum).astype(p.dtype), gsum, params)
            return grads, jax.tree.map(lambda m: m.mean(0), ms)

        grad_norm = self.metrics is not None and self.metrics.grad_norm

        def local_update(params, opt_state, batch):
            grads, metrics = mean_grads(params, batch)
            if grad_norm:
                # per-worker gradient l2 norm; executors mean it over the
                # worker axis like every other per-step metric channel
                metrics = dict(metrics)
                metrics["grad_norm"] = jnp.sqrt(sum(
                    jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads)))
            updates, opt_state = self.optimizer.update(grads, opt_state, params)
            params = jax.tree.map(jnp.add, params, updates)
            return params, opt_state, metrics

        return local_update

    # -- executor delegation ---------------------------------------------------
    def step_fn(self, event: Optional[SyncEvent], masked: bool = False):
        """The executor's compiled function for one '``event`` step'."""
        return self.executor.step_fn(event, masked)

    def round_fn(self, rnd: Round, masked: bool = False):
        """The executor's compiled function for one round; ``masked=True``
        builds the elastic-drop variant (every worker still runs its local
        updates; workers masked out of the round's sync neither contribute
        to nor receive the aggregate — they were still computing when the
        barrier closed)."""
        return self.executor.round_fn(rnd, masked)

    def step(self, state: HSGDState, batch,
             mask=None) -> Tuple[HSGDState, Dict]:
        """mask: optional (n,) bool — partial worker participation (held
        fixed by the caller within a round, re-drawn per round).  NOTE: pays
        a host sync per call (``int(state.step)``); prefer run_rounds."""
        event = self.topology.event_at(int(state.step))
        if mask is None:
            return self.step_fn(event)(state, batch)
        return self.step_fn(event, masked=True)(state, batch, jnp.asarray(mask))

    # -- schedule-compiled round executor --------------------------------------
    def run_rounds(self, state: HSGDState, batch_fn: Callable[[int], Any],
                   T: int, *, eval_every: int = 0,
                   eval_fn: Optional[Callable[[HSGDState, int], Dict]] = None,
                   trace=None, participation=None
                   ) -> Tuple[HSGDState, List[Dict]]:
        """Run T steps through the schedule-compiled executor.

        Precomputes ``topology.schedule(T)``, folds it into rounds
        (``compile_schedule``) and executes each as one fused call on the
        bound executor.  The trajectory is identical to T calls of
        :meth:`step` (tested); distinct ``Round`` signatures are compiled
        once and reused.

        History records per-step training metrics for EVERY step; when
        ``eval_every`` is set, the schedule is additionally cut at every
        ``eval_every``-th step so ``eval_fn(state, t)`` fires exactly there
        (plus at t+1 == T), and its results are merged into the matching
        record — within a round the intermediate states never materialize,
        which is where the speed comes from.

        With comms enabled, every record additionally carries ``wire_bytes``
        — the bytes the step's sync event moved (0 between syncs), computed
        statically from the payload specs (no device work).

        With a runtime model bound, every record additionally carries
        ``sim_time_s`` (the cumulative simulated makespan — the slowest
        worker's clock after that step, barrier included) and ``sim_sync_s``
        (cumulative per-level barrier link seconds, ``{"L1": ..., ...}``) —
        all host-side numpy next to the static ``wire_bytes``.  An elastic
        policy's deadline drops route the affected rounds through the
        masked executor variant; :meth:`runtime_report` has the final
        breakdown.

        With metrics enabled (``HSGD(..., metrics="on")``), the in-graph
        divergence probe pushes one row per sync event into the on-device
        :class:`~repro.obs.MetricBuffer`; this loop drains the buffer in ONE
        device→host transfer at eval boundaries (plus before the ring could
        wrap, and at the end), reattaches each row's (step, level) from the
        static schedule, and merges the values into the matching records as
        ``div_global`` / ``div_up_Lℓ`` / ``div_down_Lℓ``.  With a runtime
        bound, sync-step records also carry ``dropped`` (workers the policy
        cut from that barrier).  Records are linted against the metrics bus
        (:func:`repro.obs.validate_record`).

        ``trace`` accepts a :class:`~repro.obs.TraceRecorder`: the runtime
        clock emits per-worker compute/wait spans and per-level sync spans
        in simulated time, and drained probe rows become divergence counter
        tracks; without a runtime, spans fall back to step-index time.

        ``participation`` accepts an extra
        :class:`~repro.population.Participation` composed with the engine's
        own (topology static masks + the elastic clock): each executed
        sync consults ``round_mask`` once, and a non-None mask routes the
        round through the masked executor variant — this is how the
        population engine masks a draw's empty slots out of every sync."""
        t0 = int(state.step)
        cut = eval_every if (eval_fn is not None and eval_every) else 0
        schedule = self.topology.schedule(t0 + T)[t0:]
        rounds = compile_schedule(schedule, cut_every=cut, t0=t0)
        wire = None
        if self.comms is not None:
            ws = self.wire_stats(state)
            wire = [ws.bytes_for_event(ev) for ev in schedule]
        clock = None
        sim: List[Tuple[float, Dict[str, float]]] = []  # per-step snapshots
        if self.runtime is not None:
            clock = self.runtime.clock(self.topology,
                                       self._payload_nbytes(state),
                                       recorder=trace)
            self._last_clock = clock
        parts = self.participation(clock=clock, extra=participation) \
            if (clock is not None or participation is not None) else None
        probes = (self.metrics is not None and self.metrics.divergences
                  and state.metrics is not None)
        div_keys = self.metrics.history_keys(self.topology) if probes else ()
        cap = state.metrics.capacity if probes else 0
        pending: List[Tuple[int, int]] = []  # (step, level) since last drain
        probe_vals: Dict[int, Dict[str, float]] = {}
        drops: Dict[int, int] = {}

        def ts_of(step_no: int) -> float:
            return sim[step_no - t0 - 1][0] if clock is not None \
                else float(step_no)

        def drain(st: HSGDState) -> HSGDState:
            # one device→host transfer for everything pushed since the last
            # drain; rows get their (step, level) back from the schedule
            if not pending:
                return st
            mb = jax.device_get(st.metrics)
            k = int(mb.count)
            assert k == len(pending) <= cap, (k, len(pending), cap)
            for (step_no, lvl), row in zip(pending, mb.rows[:k]):
                vals = {key: float(v) for key, v in zip(div_keys, row)}
                probe_vals[step_no] = vals
                if trace is not None:
                    trace.divergences(step_no, lvl, ts_of(step_no), vals)
            pending.clear()
            return dataclasses.replace(st, metrics=st.metrics.reset())

        raw: List[Tuple[int, int, Dict]] = []  # (t_end, n_local, metrics)
        evals: Dict[int, Dict] = {}
        t = t0
        for rnd in rounds:
            batches = tuple(batch_fn(t + i) for i in range(rnd.n_local))
            mask = None
            if clock is not None:
                for i in range(rnd.n_local):
                    clock.advance(t + i)
                    sim.append((clock.time_s, clock.level_seconds()))
                if rnd.event is not None:
                    mask = parts.round_mask(rnd.event)
                    # the sync belongs to the round's last step
                    sim[-1] = (clock.time_s, clock.level_seconds())
            elif parts is not None and rnd.event is not None:
                mask = parts.round_mask(rnd.event)
            if clock is None and trace is not None:
                # no runtime: keep the trace well-formed in step-index time
                trace.name_process(0, "engine")
                trace.name_thread(0, 0, "rounds (step-index time)")
                trace.complete(f"round x{rnd.n_local}", float(t),
                               float(rnd.n_local), pid=0, tid=0)
                if rnd.event is not None:
                    trace.sync_span(
                        rnd.event.level, float(t + rnd.n_local), 0.0,
                        payload_bytes=wire[t + rnd.n_local - t0 - 1]
                        if wire is not None else 0)
            if probes and rnd.event is not None and len(pending) >= cap:
                state = drain(state)   # never let the ring wrap
            if mask is None:
                state, metrics = self.round_fn(rnd)(state, batches)
            else:
                state, metrics = self.round_fn(rnd, masked=True)(
                    state, batches, jnp.asarray(mask))
            t += rnd.n_local
            raw.append((t, rnd.n_local, metrics))
            if rnd.event is not None:
                if probes:
                    pending.append((t, rnd.event.level))
                if clock is not None:
                    drops[t] = 0 if mask is None else int((~mask).sum())
            if eval_fn is not None and eval_every and \
                    (t % eval_every == 0 or t == t0 + T):
                if probes:
                    state = drain(state)
                evals[t] = eval_fn(state, t - 1)
        if probes:
            state = drain(state)
        # metrics stay on device until here so rounds dispatch back-to-back;
        # one bulk transfer at the end instead of a sync per step
        history: List[Dict] = []
        for t_end, n_local, metrics in raw:
            metrics = jax.device_get(metrics)
            for i in range(n_local):
                step_no = t_end - n_local + i + 1
                rec = {"t": step_no,
                       **{k: float(v[i]) for k, v in metrics.items()}}
                if wire is not None:
                    rec["wire_bytes"] = wire[step_no - t0 - 1]
                if clock is not None:
                    time_s, sync_s = sim[step_no - t0 - 1]
                    rec["sim_time_s"] = round(time_s, 6)
                    rec["sim_sync_s"] = sync_s
                    if step_no in drops:
                        rec["dropped"] = drops[step_no]
                rec.update(probe_vals.get(step_no, {}))
                rec.update(evals.get(step_no, {}))
                history.append(rec)
        if self.metrics is not None:
            from repro.obs import validate_record
            for rec in history:
                errs = validate_record(rec)
                if errs:
                    raise ValueError(
                        "metrics-bus violations in run_rounds history at "
                        f"t={rec.get('t')}: " + "; ".join(errs))
        return state, history

    def drain_metrics(self, state: HSGDState
                      ) -> Tuple[HSGDState, List[Dict[str, float]]]:
        """Drain the probe buffer outside :meth:`run_rounds` (the per-step
        :meth:`step` path pushes rows but never drains): one device→host
        transfer, returns ``(state-with-reset-buffer, rows)`` where each row
        is a ``{div_*: value}`` dict in push order.  If more than
        ``Metrics.capacity`` rows were pushed since the last drain, only the
        most recent ``capacity`` survive (the ring wrapped)."""
        if self.metrics is None or state.metrics is None:
            return state, []
        mb = jax.device_get(state.metrics)
        k = int(mb.count)
        cap = mb.rows.shape[0]
        order = range(k) if k <= cap \
            else [i % cap for i in range(k - cap, k)]
        keys = self.metrics.history_keys(self.topology)
        rows = [{key: float(v) for key, v in zip(keys, mb.rows[i])}
                for i in order]
        return dataclasses.replace(state, metrics=state.metrics.reset()), rows

    # -- population regime -----------------------------------------------------
    def population_engine(self):
        """The lazily-built :class:`~repro.population.PopulationEngine`
        behind :meth:`run_sampled` (requires ``config.population``)."""
        if self.population is None:
            raise ValueError(
                "no population bound — construct the engine with "
                "EngineConfig(population=Population(cells=...)) to use the "
                "sampled-participation regime")
        if self._population_engine is None:
            from repro.population import PopulationEngine
            self._population_engine = PopulationEngine(self)
        return self._population_engine

    def init_server(self, key, model_init: Callable):
        """Single-replica :class:`~repro.population.ServerState` (the
        population regime's counterpart of :meth:`init` — no worker axis;
        peak state memory in this regime is bounded by k = topology.n)."""
        return self.population_engine().init_server(key, model_init)

    def run_sampled(self, server, batch_fn, rounds: int, *, sizes=None,
                    eval_every: int = 0, eval_fn=None):
        """Run ``rounds`` sampling rounds of the population regime: each
        draws k = topology.n virtual clients (hierarchically, pure in
        ``(seed, round)``), hydrates them into the (k, ...) state, runs one
        global period on the unchanged round executor, and folds the
        results back into the server model with dataset-size × staleness
        weights (``sizes``: optional ``client_id -> dataset size``, e.g.
        ``PopulationShards.client_size``).  ``batch_fn(client_ids, t)``
        returns the global step t's batch for the drawn clients (leading
        axis k).  Returns ``(ServerState, per-round history)``; each record
        carries the ``participation`` channel."""
        return self.population_engine().run(
            server, batch_fn, rounds, sizes=sizes, eval_every=eval_every,
            eval_fn=eval_fn)

    # -- inspection ------------------------------------------------------------
    def wire_stats(self, state: HSGDState):
        """Static per-level wire accounting for this engine's sync payloads
        (:class:`repro.comms.WireStats`), or None with comms disabled.
        Counts everything a sync actually ships: params, plus the optimizer
        moments when ``aggregate_opt_state`` puts them on the wire."""
        if self.comms is None:
            return None
        from repro.comms import WireArray, WireStats
        parts = [("params", state.params)]
        if self.aggregate_opt_state:
            moments = _moments_only(state.opt_state)
            if jax.tree.leaves(moments):
                parts.append(("moments", moments))
        payload: List[Any] = []
        n_elements = 0
        for name, tree in parts:
            arrays, n = self.comms.payload_spec(tree)
            payload += [WireArray(f"{name}.{a.name}", a.shape, a.dtype)
                        for a in arrays]
            n_elements += n
        return WireStats(self.topology, tuple(payload), n_elements)

    def audit(self, state: HSGDState, batch_fn: Optional[Callable] = None,
              *, T: Optional[int] = None, config: str = "", waivers=(),
              run: bool = True):
        """Static audit of this engine's lowered sync plan
        (:func:`repro.analysis.audit_engine`): traces every distinct
        SyncEvent's aggregation subprogram — and, with ``batch_fn``, every
        distinct Round's fused program — over one global period (or ``T``
        steps) and lints the result (rule catalog in DESIGN.md "Analysis
        layer").  ``run=False`` skips the run_rounds execution pass (retrace
        detection then has no jit-cache numbers — tracing only).  Returns a
        :class:`~repro.analysis.SyncPlanReport`."""
        from repro.analysis import audit_engine
        return audit_engine(self, state, batch_fn, T=T, config=config,
                            waivers=waivers, run=run)

    def _payload_nbytes(self, state: HSGDState) -> int:
        """Per-worker bytes ONE sync payload puts on the wire — the encoded
        codec payload with comms on (so compression buys simulated time),
        else the raw dtype-true bytes of everything a sync ships (params +
        aggregated optimizer moments)."""
        if self.comms is not None:
            return self.wire_stats(state).payload_bytes
        parts = [state.params]
        if self.aggregate_opt_state:
            parts.append(_moments_only(state.opt_state))
        return sum(x.nbytes // x.shape[0]
                   for tree in parts for x in jax.tree.leaves(tree))

    def runtime_report(self, state: Optional[HSGDState] = None):
        """The last :meth:`run_rounds` clock's breakdown (simulated makespan,
        per-level sync seconds, drop counts, ...), or None before any
        runtime-enabled run.  ``state`` is accepted for symmetry with
        :meth:`wire_stats` and unused."""
        if self._last_clock is None:
            return None
        return self._last_clock.breakdown()

    def mean_params(self, state: HSGDState):
        """w̄^t (the analysis object; observable only at t = aG)."""
        return jax.tree.map(
            lambda x: x.mean(0, dtype=jnp.float32).astype(x.dtype), state.params)

    def worker_params(self, state: HSGDState, j: int):
        return jax.tree.map(lambda x: x[j], state.params)


def _moments_only(opt_state):
    return {k: v for k, v in opt_state.items() if k in ("m", "v")}


def _merge_moments(opt_state, agg):
    out = dict(opt_state)
    out.update(agg)
    return out


# ---------------------------------------------------------------------------
# convenience: run T steps with a data source
# ---------------------------------------------------------------------------
def run(engine: HSGD, state: HSGDState, batch_fn: Callable[[int], Any],
        T: int, eval_every: int = 0,
        eval_fn: Optional[Callable[[HSGDState, int], Dict]] = None):
    """batch_fn(t) -> batch with leading worker axis. Returns (state, history).

    History gets one record per step with the training metrics (previously it
    was silently empty unless ``eval_every`` was set); ``eval_fn`` results are
    merged into the matching step's record every ``eval_every`` steps."""
    history = []
    for t in range(T):
        state, metrics = engine.step(state, batch_fn(t))
        rec = {"t": t + 1, **{k: float(v) for k, v in metrics.items()}}
        if eval_every and (t + 1) % eval_every == 0 and eval_fn is not None:
            rec.update(eval_fn(state, t))
        history.append(rec)
    return state, history
