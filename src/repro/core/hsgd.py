"""The H-SGD engine (paper Algorithm 1 and multi-level Algorithm D.1).

State layout: every worker owns a full model replica; ``params`` and
``opt_state`` carry a leading worker axis of size n.  One engine serves both
execution modes:

* sim  — n = tens..hundreds of CPU "workers"; used for the paper-experiment
  reproduction.  Aggregations are reshapes/means (uniform hierarchy) or
  mixing-matrix products (arbitrary fixed groupings, Theorem 1).
* mesh — n = product of replica mesh axes; the SAME code, but params are
  sharded ``P(('pod','data'), ...)`` so the level-ℓ mean lowers to an
  all-reduce over exactly the mesh axes of levels >= ℓ (local sync = intra-pod
  ICI; global sync additionally crosses the pod axis).

Because the periods are static, each distinct step kind (pure-local,
sync@level-ℓ, partial group sync) is its own jitted function — no lax.cond
around collectives, so the lowered HLO per step kind is exact (the roofline
reads it).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.grouping import Grouping
from repro.core.hierarchy import HierarchySpec
from repro.optim.optimizers import Optimizer


# ---------------------------------------------------------------------------
# topologies
# ---------------------------------------------------------------------------
class UniformTopology:
    """Uniform multi-level hierarchy (HierarchySpec); reshape-based means.
    Works identically in sim and mesh mode.

    sync_dtype: dtype of the aggregation payload.  float32 (default) is the
    exact paper semantics; 'bfloat16' halves the collective bytes of every
    sync (a beyond-paper §Perf option — the paper calls compression
    orthogonal, we make it a first-class switch)."""

    def __init__(self, spec: HierarchySpec, sync_dtype: str = "float32"):
        self.spec = spec
        self.n = spec.n_workers
        self.periods = spec.periods
        self.sync_dtype = sync_dtype

    def step_kind(self, t: int) -> Optional[Tuple[str, int]]:
        lvl = self.spec.sync_level(t)
        return None if lvl is None else ("level", lvl)

    def aggregate(self, tree, kind, mask: Optional[jax.Array] = None) -> Any:
        """mask (n,) float/bool: partial worker participation (paper App. E
        experiments / stated future work) — the level-ℓ mean runs over the
        participating workers only; everyone receives the result."""
        _, lvl = kind
        gs = self.spec.group_sizes
        m = len(gs)
        acc = jnp.dtype(self.sync_dtype)

        def agg(x):
            shaped = x.reshape(gs + x.shape[1:])
            axes = tuple(range(lvl - 1, m))
            if mask is None:
                # dtype=acc pins the ACCUMULATION dtype: without it jnp.mean
                # upcasts bf16 sums to f32 and the sync all-reduce payload
                # stays f32 (measured in §Perf)
                mean = shaped.astype(acc).mean(axis=axes, keepdims=True,
                                               dtype=acc).astype(x.dtype)
            else:
                w = mask.astype(acc).reshape(gs + (1,) * (shaped.ndim - m))
                num = (shaped.astype(acc) * w).sum(axis=axes, keepdims=True,
                                                   dtype=acc)
                den = jnp.maximum(w.sum(axis=axes, keepdims=True, dtype=acc),
                                  1e-9)
                mean = (num / den).astype(x.dtype)
            return jnp.broadcast_to(mean, shaped.shape).reshape(x.shape)

        return jax.tree.map(agg, tree)


class GroupedTopology:
    """Two-level H-SGD with an explicit (possibly non-uniform) Grouping and
    per-group local periods I_i (Theorem 1's most general setting)."""

    def __init__(self, grouping: Grouping, G: int,
                 I: Union[int, Tuple[int, ...]]):
        self.grouping = grouping
        self.n = grouping.n
        self.G = G
        self.I = tuple([I] * grouping.N) if isinstance(I, int) else tuple(I)
        assert len(self.I) == grouping.N
        for Ii in self.I:
            assert G % Ii == 0, (G, Ii)
        self.periods = (G, min(self.I))
        self._A_loc = np.asarray(grouping.local_matrix())
        self._A_glob = np.asarray(grouping.global_matrix())

    def step_kind(self, t: int):
        if (t + 1) % self.G == 0:
            return ("global",)
        mask = tuple(bool((t + 1) % Ii == 0) for Ii in self.I)
        return ("groups", mask) if any(mask) else None

    def _matrix(self, kind) -> np.ndarray:
        if kind[0] == "global":
            return self._A_glob
        mask = np.asarray(kind[1])
        a = np.asarray(self.grouping.assignment)
        keep = mask[a]                      # workers whose group syncs now
        A = np.where(keep[:, None], self._A_loc, np.eye(self.n))
        return A

    def aggregate(self, tree, kind, mask: Optional[jax.Array] = None):
        if mask is None:
            A = jnp.asarray(self._matrix(kind), jnp.float32)

            def agg(x):
                flat = x.reshape(self.n, -1).astype(jnp.float32)
                out = A @ flat
                return out.astype(x.dtype).reshape(x.shape)

            return jax.tree.map(agg, tree)
        # partial participation: group means over participants, distributed
        # to every member of a syncing group (Algorithm 1 semantics)
        oh = jnp.asarray(self.grouping.onehot(), jnp.float32)      # (N, n)
        a = np.asarray(self.grouping.assignment)
        if kind[0] == "global":
            syncing = np.ones(self.grouping.N, bool)
        else:
            syncing = np.asarray(kind[1])
        wm = mask.astype(jnp.float32)

        def agg(x):
            flat = x.reshape(self.n, -1).astype(jnp.float32)
            num = oh @ (wm[:, None] * flat)                        # (N, dim)
            den = jnp.maximum(oh @ wm, 1e-9)[:, None]
            gm = num / den
            if kind[0] == "global":
                val = jnp.broadcast_to(gm.mean(0, keepdims=True),
                                       (self.n, flat.shape[1]))
            else:
                val = gm[a]
            out = jnp.where(jnp.asarray(syncing[a])[:, None], val, flat)
            return out.astype(x.dtype).reshape(x.shape)

        return jax.tree.map(agg, tree)


Topology = Union[UniformTopology, GroupedTopology]


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HSGDState:
    params: Any      # leading worker axis n
    opt_state: Any   # leading worker axis n
    step: jax.Array  # scalar int32


class HSGD:
    """loss_fn(params, batch) -> (loss, metrics-dict). Batch passed to
    ``step`` must carry a leading worker axis of size n."""

    def __init__(self, loss_fn: Callable, optimizer: Optimizer,
                 topology: Topology, *, aggregate_opt_state: bool = True,
                 jit: bool = True, accum_steps: int = 1):
        """accum_steps > 1: each H-SGD iteration accumulates gradients over
        that many microbatches (scan) before the single optimizer update —
        same semantics as one large-batch step (SGD is linear in the
        gradient; tested), peak activation memory divided by accum_steps."""
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.topology = topology
        self.aggregate_opt_state = aggregate_opt_state
        self._jit = jit
        self.accum_steps = accum_steps
        self._step_fns: Dict[Any, Callable] = {}

    # -- init ---------------------------------------------------------------
    def init(self, key, model_init: Callable[[jax.Array], Any]) -> HSGDState:
        """All workers start from the SAME w̄^0 (paper input)."""
        params0 = model_init(key)
        n = self.topology.n
        params = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params0)
        opt0 = self.optimizer.init(params0)
        opt_state = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), opt0)
        return HSGDState(params, opt_state, jnp.zeros((), jnp.int32))

    # -- one combined step per kind ------------------------------------------
    def _build_step(self, kind, masked: bool = False):
        grad_fn = jax.grad(lambda p, b: self.loss_fn(p, b), has_aux=True)
        accum = self.accum_steps

        def mean_grads(params, batch):
            if accum == 1:
                return grad_fn(params, batch)

            def micro(acc, mb):
                g, m = grad_fn(params, mb)
                return jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g), m

            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            gsum, ms = jax.lax.scan(micro, zero, mbs)
            grads = jax.tree.map(
                lambda g, p: (g / accum).astype(p.dtype), gsum, params)
            return grads, jax.tree.map(lambda m: m.mean(0), ms)

        def local_update(params, opt_state, batch):
            grads, metrics = mean_grads(params, batch)
            updates, opt_state = self.optimizer.update(grads, opt_state, params)
            params = jax.tree.map(jnp.add, params, updates)
            return params, opt_state, metrics

        def apply_mask(new, old, mask):
            """Non-participating workers keep their previous state."""
            def sel(a, b):
                m = mask.reshape((-1,) + (1,) * (a.ndim - 1))
                return jnp.where(m, a, b)
            return jax.tree.map(sel, new, old)

        def step(state: HSGDState, batch, mask=None) -> Tuple[HSGDState, Dict]:
            params, opt_state, metrics = jax.vmap(local_update)(
                state.params, state.opt_state, batch)
            if masked:
                params = apply_mask(params, state.params, mask)
                opt_state = apply_mask(opt_state, state.opt_state, mask)
            if kind is not None:
                amask = mask if masked else None
                params = self.topology.aggregate(params, kind, mask=amask)
                if self.aggregate_opt_state:
                    # average optimizer moments with the same schedule as the
                    # params (paper's SGD has none; momentum/adam extension)
                    agg = self.topology.aggregate(_moments_only(opt_state),
                                                  kind, mask=amask)
                    opt_state = _merge_moments(opt_state, agg)
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
            return HSGDState(params, opt_state, state.step + 1), metrics

        if not self._jit:
            return step
        return jax.jit(step, donate_argnums=0) if masked else \
            jax.jit(lambda s, b: step(s, b), donate_argnums=0)

    def step_fn(self, kind, masked: bool = False):
        key = (kind, masked)
        if key not in self._step_fns:
            self._step_fns[key] = self._build_step(kind, masked)
        return self._step_fns[key]

    def step(self, state: HSGDState, batch,
             mask=None) -> Tuple[HSGDState, Dict]:
        """mask: optional (n,) bool — partial worker participation (held
        fixed by the caller within a round, re-drawn per round)."""
        kind = self.topology.step_kind(int(state.step))
        if mask is None:
            return self.step_fn(kind)(state, batch)
        return self.step_fn(kind, masked=True)(state, batch, jnp.asarray(mask))

    # -- inspection ------------------------------------------------------------
    def mean_params(self, state: HSGDState):
        """w̄^t (the analysis object; observable only at t = aG)."""
        return jax.tree.map(
            lambda x: x.mean(0, dtype=jnp.float32).astype(x.dtype), state.params)

    def worker_params(self, state: HSGDState, j: int):
        return jax.tree.map(lambda x: x[j], state.params)


def _moments_only(opt_state):
    return {k: v for k, v in opt_state.items() if k in ("m", "v")}


def _merge_moments(opt_state, agg):
    out = dict(opt_state)
    out.update(agg)
    return out


# ---------------------------------------------------------------------------
# convenience: run T steps with a data source
# ---------------------------------------------------------------------------
def run(engine: HSGD, state: HSGDState, batch_fn: Callable[[int], Any],
        T: int, eval_every: int = 0,
        eval_fn: Optional[Callable[[HSGDState, int], Dict]] = None):
    """batch_fn(t) -> batch with leading worker axis. Returns (state, history)."""
    history = []
    for t in range(T):
        state, metrics = engine.step(state, batch_fn(t))
        if eval_every and (t + 1) % eval_every == 0 and eval_fn is not None:
            rec = {"t": t + 1, **{k: float(v) for k, v in metrics.items()}}
            rec.update(eval_fn(state, t))
            history.append(rec)
    return state, history
