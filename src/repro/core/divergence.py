"""Upward / downward / global gradient divergences (paper Assumptions 1c/1d/2,
partition identity eq. (10), and Lemma 1/2 empirical expectations).

All functions take per-worker gradients evaluated at a COMMON point w
(that is how the paper defines divergence), stacked as (n, dim) float arrays
(pytrees are flattened by the caller or via ``stack_grads``).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.grouping import Grouping


def flatten_pytree_batch(grads) -> jnp.ndarray:
    """pytree with leading worker dim -> (n, dim)."""
    leaves = [jnp.reshape(l, (l.shape[0], -1)) for l in jax.tree.leaves(grads)]
    return jnp.concatenate(leaves, axis=1)


def global_divergence(g: jnp.ndarray) -> jnp.ndarray:
    """(1/n) sum_j ||g_j - mean||^2  — Assumption 2's LHS."""
    mean = g.mean(0)
    return jnp.mean(jnp.sum((g - mean) ** 2, axis=1))


def group_means(g: jnp.ndarray, grouping: Grouping) -> jnp.ndarray:
    oh = jnp.asarray(grouping.onehot(), g.dtype)           # (N, n)
    sums = oh @ g                                          # (N, dim)
    return sums / jnp.asarray(grouping.sizes, g.dtype)[:, None]


def upward_divergence(g: jnp.ndarray, grouping: Grouping) -> jnp.ndarray:
    """sum_i (n_i/n) ||grad f_i - grad f||^2 — Assumption 1c's LHS.
    grad f is the n_i/n-weighted mean (paper eq. (2))."""
    gm = group_means(g, grouping)                          # (N, dim)
    w = jnp.asarray(grouping.sizes, g.dtype) / grouping.n  # (N,)
    gbar = (w[:, None] * gm).sum(0)
    return jnp.sum(w * jnp.sum((gm - gbar) ** 2, axis=1))


def downward_divergences(g: jnp.ndarray, grouping: Grouping) -> jnp.ndarray:
    """per-group (1/n_i) sum_{j in V_i} ||g_j - grad f_i||^2 — Assumption 1d.

    The per-worker group mean is scattered back with the one-hot transpose
    (``ohᵀ @ gm``) rather than a gather on the assignment vector: identical
    values (one-hot rows select exactly one mean), but no integer-constant
    ``device_put`` in the traced program — this function is also the
    in-graph probe body, and rule R3/R6 hold round bodies transfer-free."""
    gm = group_means(g, grouping)                          # (N, dim)
    oh = jnp.asarray(grouping.onehot(), g.dtype)           # (N, n)
    diffs = jnp.sum((g - oh.T @ gm) ** 2, axis=1)          # (n,)
    return (oh @ diffs) / jnp.asarray(grouping.sizes, g.dtype)


def downward_divergence_avg(g: jnp.ndarray, grouping: Grouping) -> jnp.ndarray:
    """sum_i (n_i/n) * eps_i^2-term = (1/n) sum_i sum_{j in V_i} ||.||^2."""
    w = jnp.asarray(grouping.sizes, g.dtype) / grouping.n
    return jnp.sum(w * downward_divergences(g, grouping))


def partition_residual(g: jnp.ndarray, grouping: Grouping) -> jnp.ndarray:
    """eq. (10): global = upward + weighted downward (exact for uniform
    weights; returns the residual so tests can assert ~0)."""
    return (global_divergence(g)
            - upward_divergence(g, grouping)
            - downward_divergence_avg(g, grouping))


def partition_divergences(g: jnp.ndarray, groupings) -> jnp.ndarray:
    """The eq. (10) partition row ``[global, up_1, down_1, up_2, ...]`` for
    every grouping in ``groupings``, fused.

    This is the in-graph probe's formula (:meth:`repro.obs.Metrics.
    sim_row_fn`): center once (``y = g - mean``), then every term is a
    sum-of-squares identity on y — ``global = E||y_j||^2``,
    ``up = sum_i w_i ||gm_i(y)||^2`` and ``down = global - up`` (exact:
    E||y - gm||^2 = E||y||^2 - ||gm||^2 per group, so the partition holds
    by construction).  One pass over the (n, dim) block plus one group-mean
    contraction per level — no full-size temporaries per term, which is
    what keeps the probe inside the R6 overhead contract.  Centering first
    keeps the decomposition cancellation-free: every squared norm is
    already on the divergence scale.  The naive per-term formulas above are
    the independent oracle the probe is tested against."""
    y = g - g.mean(0)
    total = jnp.mean(jnp.sum(y * y, axis=1))
    out = [total]
    for grouping in groupings:
        gm = group_means(y, grouping)                      # (N, dim)
        w = jnp.asarray(grouping.sizes, g.dtype) / grouping.n
        up = jnp.sum(w * jnp.sum(gm * gm, axis=1))
        out += [up, total - up]
    return jnp.stack(out)


def _lift_matrices(groupings):
    """For NESTED groupings (outermost first — an H-SGD hierarchy's
    ``level_groupings``), the (N_l, N_fin) maps taking finest-level group
    means to each coarser level's group means.  None when the groupings
    are not nested (independent partitions: no lift exists)."""
    fin = groupings[-1]
    ohf = np.asarray(fin.onehot(), np.float64)             # (Nf, n)
    lifts = []
    for g in groupings[:-1]:
        counts = np.asarray(g.onehot(), np.float64) @ ohf.T  # workers in both
        if (np.count_nonzero(counts, axis=0) != 1).any():
            return None
        # float64 on purpose: ``jnp.asarray(lift, jnp.float32)`` in the
        # traced probe then lowers as a dtype-converted constant, not a
        # ``device_put`` transfer (rule R3 keeps round bodies transfer-free)
        lifts.append(counts / np.asarray(g.sizes, np.float64)[:, None])
    return lifts


def partition_divergences_tree(params, groupings) -> jnp.ndarray:
    """:func:`partition_divergences` evaluated leaf-by-leaf on a pytree
    with a leading worker dim — the sum-of-squares terms are additive over
    leaves, so the (n, dim) flatten/concat (a full param-set copy per
    probe) never materializes.  This is what the in-graph probe lowers.

    For nested groupings only the FINEST level touches the (n, dim) block:
    its group means come from one contraction, the global mean and every
    coarser level's means are weighted combinations of those (tiny), and
    the only other full-size pass is the fused centered-norm reduction for
    the global term — two passes over the params per probe, independent of
    the number of levels.  Non-nested groupings fall back to one
    contraction per level."""
    leaves = [jnp.reshape(l, (l.shape[0], -1)).astype(jnp.float32)
              for l in jax.tree.leaves(params)]
    total = jnp.zeros((), jnp.float32)
    ups = [jnp.zeros((), jnp.float32) for _ in groupings]

    def up_term(gm_centered, grouping):
        w = jnp.asarray(grouping.sizes, jnp.float32) / grouping.n
        return jnp.sum(w * jnp.sum(gm_centered * gm_centered, axis=1))

    lifts = _lift_matrices(groupings) if groupings else None
    if lifts is not None:
        lifts = [jnp.asarray(l, jnp.float32) for l in lifts]
    for x in leaves:
        if lifts is None:
            y = x - x.mean(0)
            total = total + jnp.mean(jnp.sum(y * y, axis=1))
            for i, grouping in enumerate(groupings):
                ups[i] = ups[i] + up_term(group_means(y, grouping), grouping)
            continue
        fin = groupings[-1]
        gmf = group_means(x, fin)                          # (Nf, dim)
        wf = jnp.asarray(fin.sizes, jnp.float32) / fin.n
        xbar = jnp.sum(wf[:, None] * gmf, axis=0)          # global mean
        total = total + jnp.mean(jnp.sum((x - xbar) ** 2, axis=1))
        gmfc = gmf - xbar
        ups[-1] = ups[-1] + up_term(gmfc, fin)
        for i, lift in enumerate(lifts):
            ups[i] = ups[i] + up_term(lift @ gmfc, groupings[i])
    out = [total]
    for up in ups:
        out += [up, total - up]
    return jnp.stack(out)


def divergence_stack(g: jnp.ndarray, grouping: Grouping) -> jnp.ndarray:
    """All four divergence summaries as ONE stacked device array
    ``[global, upward, downward_avg, downward_max]`` — a single fused
    computation whose group means are shared across the four outputs,
    so callers pay one device→host transfer instead of four."""
    dd = downward_divergences(g, grouping)
    w = jnp.asarray(grouping.sizes, g.dtype) / grouping.n
    return jnp.stack([
        global_divergence(g),
        upward_divergence(g, grouping),
        jnp.sum(w * dd),
        dd.max(),
    ])


def all_divergences(g: jnp.ndarray, grouping: Grouping) -> Dict[str, float]:
    """Host-side divergence summary.  One device→host transfer: the four
    scalars come back as a single stacked array (``divergence_stack``), not
    four separate ``float(...)`` syncs."""
    vals = np.asarray(divergence_stack(g, grouping))
    return {
        "global": float(vals[0]),
        "upward": float(vals[1]),
        "downward_avg": float(vals[2]),
        "downward_max": float(vals[3]),
    }


def per_worker_grads(loss_fn, params, batches) -> jnp.ndarray:
    """Gradients of every worker's loss at a COMMON params point.
    batches: pytree with leading worker dim.  Returns (n, dim)."""
    gfn = jax.grad(lambda p, b: loss_fn(p, b)[0])
    grads = jax.vmap(gfn, in_axes=(None, 0))(params, batches)
    return flatten_pytree_batch(grads)
