"""Upward / downward / global gradient divergences (paper Assumptions 1c/1d/2,
partition identity eq. (10), and Lemma 1/2 empirical expectations).

All functions take per-worker gradients evaluated at a COMMON point w
(that is how the paper defines divergence), stacked as (n, dim) float arrays
(pytrees are flattened by the caller or via ``stack_grads``).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.grouping import Grouping


def flatten_pytree_batch(grads) -> jnp.ndarray:
    """pytree with leading worker dim -> (n, dim)."""
    leaves = [jnp.reshape(l, (l.shape[0], -1)) for l in jax.tree.leaves(grads)]
    return jnp.concatenate(leaves, axis=1)


def global_divergence(g: jnp.ndarray) -> jnp.ndarray:
    """(1/n) sum_j ||g_j - mean||^2  — Assumption 2's LHS."""
    mean = g.mean(0)
    return jnp.mean(jnp.sum((g - mean) ** 2, axis=1))


def group_means(g: jnp.ndarray, grouping: Grouping) -> jnp.ndarray:
    oh = jnp.asarray(grouping.onehot(), g.dtype)           # (N, n)
    sums = oh @ g                                          # (N, dim)
    return sums / jnp.asarray(grouping.sizes, g.dtype)[:, None]


def upward_divergence(g: jnp.ndarray, grouping: Grouping) -> jnp.ndarray:
    """sum_i (n_i/n) ||grad f_i - grad f||^2 — Assumption 1c's LHS.
    grad f is the n_i/n-weighted mean (paper eq. (2))."""
    gm = group_means(g, grouping)                          # (N, dim)
    w = jnp.asarray(grouping.sizes, g.dtype) / grouping.n  # (N,)
    gbar = (w[:, None] * gm).sum(0)
    return jnp.sum(w * jnp.sum((gm - gbar) ** 2, axis=1))


def downward_divergences(g: jnp.ndarray, grouping: Grouping) -> jnp.ndarray:
    """per-group (1/n_i) sum_{j in V_i} ||g_j - grad f_i||^2 — Assumption 1d."""
    gm = group_means(g, grouping)                          # (N, dim)
    a = np.asarray(grouping.assignment)
    diffs = jnp.sum((g - gm[a]) ** 2, axis=1)              # (n,)
    oh = jnp.asarray(grouping.onehot(), g.dtype)
    return (oh @ diffs) / jnp.asarray(grouping.sizes, g.dtype)


def downward_divergence_avg(g: jnp.ndarray, grouping: Grouping) -> jnp.ndarray:
    """sum_i (n_i/n) * eps_i^2-term = (1/n) sum_i sum_{j in V_i} ||.||^2."""
    w = jnp.asarray(grouping.sizes, g.dtype) / grouping.n
    return jnp.sum(w * downward_divergences(g, grouping))


def partition_residual(g: jnp.ndarray, grouping: Grouping) -> jnp.ndarray:
    """eq. (10): global = upward + weighted downward (exact for uniform
    weights; returns the residual so tests can assert ~0)."""
    return (global_divergence(g)
            - upward_divergence(g, grouping)
            - downward_divergence_avg(g, grouping))


def all_divergences(g: jnp.ndarray, grouping: Grouping) -> Dict[str, float]:
    return {
        "global": float(global_divergence(g)),
        "upward": float(upward_divergence(g, grouping)),
        "downward_avg": float(downward_divergence_avg(g, grouping)),
        "downward_max": float(downward_divergences(g, grouping).max()),
    }


def per_worker_grads(loss_fn, params, batches) -> jnp.ndarray:
    """Gradients of every worker's loss at a COMMON params point.
    batches: pytree with leading worker dim.  Returns (n, dim)."""
    gfn = jax.grad(lambda p, b: loss_fn(p, b)[0])
    grads = jax.vmap(gfn, in_axes=(None, 0))(params, batches)
    return flatten_pytree_batch(grads)
