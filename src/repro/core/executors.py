"""The executor layer: HOW a compiled H-SGD round runs on hardware.

The plan layer (:mod:`repro.core.hsgd`) decides *what* happens — n_local
local updates, then a typed :class:`~repro.core.topology.SyncEvent` — and
hands each :class:`~repro.core.hsgd.Round` to an ``Executor`` that owns the
device mapping and the lowering of the sync collective:

* :class:`SimExecutor` — the reproduction backend.  One device; ``params``
  carry a leading worker axis that is vmapped for the local updates and
  aggregated with in-array segment/reshape means via ``topology.aggregate``.
  Bitwise-identical to the paper experiments (it IS the old single-path
  engine, extracted).
* :class:`MeshExecutor` — the deployment backend.  The round body runs under
  ``jax.shard_map`` on a mesh whose replica axes mirror the hierarchy levels
  (``launch.mesh.make_hsgd_mesh``: outermost axis = level 1 = the slow
  DCI/pod fabric), one worker per replica coordinate.  Each
  ``SyncEvent(level=ℓ)`` lowers to a ``lax.pmean`` over exactly the mesh
  axes of levels >= ℓ (``topology.level_axes`` names them, the aggregator's
  ``axis_aggregate`` supplies the encode/pmean/decode rule) — what the
  engine docstring always promised, now emitted explicitly instead of left
  to GSPMD luck.  ``GroupedTopology`` lowers over the FLAT worker axis with
  one-hot membership weights, and runtime participation masks (Algorithm-1
  partial participation, elastic-deadline drops) thread in as per-worker
  collective weights — every scenario the simulator runs also runs here.

Both backends implement the same **masked-round contract** (what a worker
excluded from a sync keeps — see :class:`MeshExecutor` for the table) and
the same ``exact=True``-replayable reduce, so sim is always the bitwise
reference for mesh verification.  DESIGN.md §2 is the full lowering
contract.

Executors are constructed via :func:`make_executor` ("sim" | "mesh" | an
instance) and bound to one engine; compiled step/round functions are cached
per (event, masked) / per Round exactly as before.
"""
from __future__ import annotations

import abc
import math
from typing import Any, Dict, Optional, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.comms.reduce import ExactWireOps, MeshWireOps, SimWireOps
from repro.core.aggregators import Aggregator, flat_worker_index
from repro.core.hsgd import (HSGDState, Round, _merge_moments, _moments_only)
from repro.core.topology import SyncEvent


class Executor(abc.ABC):
    """Backend contract: build (and cache) the compiled step/round bodies
    for one bound plan-layer engine."""

    def __init__(self):
        self.plan = None
        self._step_fns: Dict[Any, Any] = {}
        self._round_fns: Dict[Any, Any] = {}

    # -- lifecycle ----------------------------------------------------------
    def bind(self, plan) -> "Executor":
        """Attach to an :class:`~repro.core.hsgd.HSGD` plan (called by its
        constructor).  One executor serves one engine."""
        assert self.plan is None or self.plan is plan, \
            "executor is already bound to another engine"
        self.plan = plan
        self._validate()
        return self

    def _validate(self) -> None:
        """Check the bound plan is executable on this backend (fail fast)."""

    def twin(self) -> "Executor":
        """A fresh UNBOUND executor with this one's settings, for derived
        engines (the analysis layer's metrics-off twin, the population
        engine's inner engine) — one executor instance serves one engine."""
        return type(self)()

    def place(self, state: HSGDState) -> HSGDState:
        """Move a freshly initialized state onto this backend's layout."""
        return state

    # -- compiled-function caches -------------------------------------------
    def step_fn(self, event: Optional[SyncEvent], masked: bool = False):
        key = (event, masked)
        if key not in self._step_fns:
            self._step_fns[key] = self._build_step(event, masked)
        return self._step_fns[key]

    def round_fn(self, rnd: Round, masked: bool = False):
        key = (rnd, masked)
        if key not in self._round_fns:
            self._round_fns[key] = self._build_round(rnd, masked)
        return self._round_fns[key]

    @abc.abstractmethod
    def _build_step(self, event: Optional[SyncEvent], masked: bool = False):
        ...

    @abc.abstractmethod
    def _build_round(self, rnd: Round, masked: bool = False):
        ...

    # -- static-analysis surface (repro.analysis) ----------------------------
    @abc.abstractmethod
    def sync_fn(self, event: SyncEvent):
        """The UNcompiled aggregation subprogram one sync event embeds in
        every round body: ``(params, opt_state, cstate, mask=None) ->
        (params, opt_state, cstate)``.  This is the exact reduce path
        ``round_fn`` lowers (same closure, same collectives) exposed in
        isolation, so the analysis layer can trace WHAT an event ships
        without the local-update noise around it."""

    def sync_jaxpr(self, event: SyncEvent, state: HSGDState, mask=None):
        """ClosedJaxpr of :meth:`sync_fn` against ``state``'s shapes — the
        trace target of the ``repro.analysis`` walker (rules R1/R2/R5)."""
        fn = self.sync_fn(event)
        if mask is None:
            return jax.make_jaxpr(lambda p, o, c: fn(p, o, c))(
                state.params, state.opt_state, state.comms)
        return jax.make_jaxpr(lambda p, o, c, m: fn(p, o, c, mask=m))(
            state.params, state.opt_state, state.comms, jnp.asarray(mask))

    def round_jaxpr(self, rnd: Round, state: HSGDState, batches, mask=None):
        """ClosedJaxpr of the compiled round body for one ``Round``
        signature — the same cached function ``run_rounds`` dispatches
        (tracing it here warms nothing and compiles nothing), walked by
        ``repro.analysis`` for rules R3/R4 and the per-round collective
        budget."""
        fn = self.round_fn(rnd, masked=mask is not None)
        if mask is None:
            return jax.make_jaxpr(lambda s, b: fn(s, b))(state, batches)
        return jax.make_jaxpr(lambda s, b, m: fn(s, b, m))(
            state, batches, jnp.asarray(mask))


def _wire_eligible(plan, event: SyncEvent) -> bool:
    """Can this event's sync lower as a compressed collective
    (:meth:`Comms.sync` with a ``reduce_mode``)?  The wire path reproduces
    exactly the default lowering — bucketized payloads, uniform hierarchy,
    the aggregator's stock f32 encode/mean/decode, no static per-worker or
    per-event weights — so anything bespoke falls back to the legacy
    encode→reduce→decode roundtrip unchanged.  Runtime masks ARE supported
    (they thread into the WireOps)."""
    comms = plan.comms
    if comms is None or not (comms.wire_reduce and comms.codec.wire_reduce
                             and comms.bucket):
        return False
    topo = plan.topology
    if getattr(topo, "spec", None) is None:       # grouped: segment means
        return False
    if event.groups is not None or event.weights is not None:
        return False
    agg = topo.aggregator
    if type(agg).encode is not Aggregator.encode or \
            type(agg).decode is not Aggregator.decode:
        return False                              # custom wire hooks
    if agg.worker_weights(topo.n) is not None:
        return False                              # weighted means
    return jnp.dtype(agg.accum_dtype) == jnp.dtype(jnp.float32)


def _apply_sync(plan, reduce_fn, params, opt_state, cstate, wire=None):
    """Shared sync dispatch for both executors: apply ``reduce_fn`` (the
    backend's aggregation — topology segment-means under sim, named-axis
    collectives under mesh) either directly or through the comms wire
    (bucketize + codec roundtrip + reduce), optimizer moments riding the
    same path (stateless: no error feedback on moments).  ``wire`` is the
    backend's :class:`~repro.comms.reduce.WireOps` when the event lowers as
    a compressed collective (see :func:`_wire_eligible`), else None."""
    if plan.comms is None:
        params = reduce_fn(params)
        if plan.aggregate_opt_state:
            opt_state = _merge_moments(
                opt_state, reduce_fn(_moments_only(opt_state)))
        return params, opt_state, cstate
    params, cstate = plan.comms.sync(params, reduce_fn, residual=cstate,
                                     reduce_mode=wire)
    if plan.aggregate_opt_state:
        agg, _ = plan.comms.sync(_moments_only(opt_state), reduce_fn,
                                 reduce_mode=wire)
        opt_state = _merge_moments(opt_state, agg)
    return params, opt_state, cstate


def _keep_rows(mask, new, old):
    """Row-select on the leading worker axis: mask True -> ``new``, False ->
    ``old`` — the one definition of per-worker state selection (runtime
    participation masks, partial-group restores)."""
    def sel(a, b):
        m = mask.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(m, a, b)
    return jax.tree.map(sel, new, old)


def _keep_shard(keep, new, old):
    """Per-shard counterpart of :func:`_keep_rows`: ``keep`` is this
    worker's scalar bool, selecting its whole shard (mesh backend, where
    each shard holds exactly one worker's row)."""
    return jax.tree.map(lambda a, b: jnp.where(keep, a, b), new, old)


def _stack_batches(n_local: int, batches):
    """length-``n_local`` tuple of per-step batches -> one (n_local, ...)
    stacked pytree, INSIDE the jitted graph so one round is exactly one
    dispatch (no host-side jnp.stack per round)."""
    if n_local == 1:
        return jax.tree.map(lambda x: x[None], batches[0])
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)


# ---------------------------------------------------------------------------
# sim: vmap over the worker axis on one device (the paper-experiment path)
# ---------------------------------------------------------------------------
class SimExecutor(Executor):
    """n = tens..hundreds of CPU "workers" on one device; aggregations are
    reshapes/means (uniform hierarchy) or membership segment-means (arbitrary
    fixed groupings, Theorem 1) through ``topology.aggregate``.

    With a comms plan bound, every sync routes through
    ``plan.comms.sync``: the tree is fused into flat per-dtype buckets,
    each worker's payload codec-roundtripped (error-feedback residuals
    threaded through ``HSGDState.comms``), and ``topology.aggregate`` runs
    on the O(dtypes) buffers — the aggregator rule is applied unchanged.

    **Masked-round contract** (shared with ``MeshExecutor``, which must
    replay it bitwise in exact mode): ``step_fn(event, masked=True)`` is
    Algorithm-1 partial participation — a masked-out worker's update is
    discarded and it still receives the aggregate; ``round_fn(rnd,
    masked=True)`` is the elastic-drop semantics — a dropped worker ran its
    local updates but neither contributes to nor receives the aggregate,
    keeping its exact post-update params, opt state and unconsumed comms
    residuals (see :meth:`_apply_event`)."""

    def _apply_event(self, params, opt_state, cstate, event: SyncEvent,
                     mask=None, drop: bool = False):
        """``mask`` weights the aggregation over participating workers only.
        ``drop=False`` is the classic runtime-mask semantics: masked-out
        workers still RECEIVE the aggregate (Algorithm 1 — they are present,
        they just contributed nothing).  ``drop=True`` is the elastic-
        deadline semantics: masked-out workers neither contribute nor
        receive — they were still computing when the barrier closed, so they
        keep their exact post-update params, opt state and unconsumed comms
        residuals (the elastic-participation contract; tested)."""
        plan = self.plan
        reduce_fn = lambda tree: plan.topology.aggregate(tree, event,
                                                         mask=mask)
        wire = SimWireOps(plan.topology.spec.group_sizes, event.level,
                          mask) if _wire_eligible(plan, event) else None
        new_p, new_o, new_c = _apply_sync(plan, reduce_fn, params, opt_state,
                                          cstate, wire=wire)
        if drop:
            keep = jnp.asarray(mask).astype(bool)
            new_p = _keep_rows(keep, new_p, params)
            new_o = _keep_rows(keep, new_o, opt_state)
            if cstate is not None:
                new_c = _keep_rows(keep, new_c, cstate)
        if plan.comms is not None:
            # topology.aggregate keeps non-participants' rows untouched, but
            # the comms path hands it codec-roundtripped payloads — restore
            # the true state (and unconsumed residual) of workers a
            # partial-group event did not sync
            part = plan.topology.participants(event)
            if part is not None:
                keep = jnp.asarray(part)
                new_p = _keep_rows(keep, new_p, params)
                new_o = _keep_rows(keep, new_o, opt_state)
                if cstate is not None:
                    new_c = _keep_rows(keep, new_c, cstate)
            if mask is not None and cstate is not None:
                # runtime-masked workers still RECEIVE the aggregate
                # (Algorithm 1) but transmitted nothing: their
                # error-feedback residual must not be consumed
                new_c = _keep_rows(jnp.asarray(mask).astype(bool),
                                   new_c, cstate)
        return new_p, new_o, new_c

    def sync_fn(self, event: SyncEvent):
        def sync(params, opt_state, cstate, mask=None):
            return self._apply_event(params, opt_state, cstate, event,
                                     mask=mask)
        return sync

    def _probe_row_fn(self, event: Optional[SyncEvent]):
        """The in-graph divergence probe for sync steps (None when metrics
        are off, divergences disabled, or there is no event).  Pushed BEFORE
        :meth:`_apply_event` so it measures the PRE-aggregation worker
        params — the live eq. (10) partition."""
        plan = self.plan
        if event is None or plan.metrics is None \
                or not plan.metrics.divergences:
            return None
        return plan.metrics.sim_row_fn(plan.topology)

    # -- one combined step per event ------------------------------------------
    def _build_step(self, event: Optional[SyncEvent], masked: bool = False):
        local_update = self.plan.local_update_fn()
        row_fn = self._probe_row_fn(event)

        def step(state: HSGDState, batch, mask=None):
            params, opt_state, metrics = jax.vmap(local_update)(
                state.params, state.opt_state, batch)
            cstate, mbuf = state.comms, state.metrics
            if masked:
                # non-participating workers keep their previous state
                params = _keep_rows(mask, params, state.params)
                opt_state = _keep_rows(mask, opt_state, state.opt_state)
            if event is not None:
                if row_fn is not None and mbuf is not None:
                    mbuf = mbuf.push(row_fn(params))
                amask = mask if masked else None
                params, opt_state, cstate = self._apply_event(
                    params, opt_state, cstate, event, mask=amask)
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
            return HSGDState(params, opt_state, state.step + 1,
                             cstate, mbuf), metrics

        if not self.plan._jit:
            return step
        return jax.jit(step, donate_argnums=0) if masked else \
            jax.jit(lambda s, b: step(s, b), donate_argnums=0)

    def _build_round(self, rnd: Round, masked: bool = False):
        """One jitted function for '``n_local`` local steps then sync': the
        local block is a single ``lax.scan`` over the stacked batches, so the
        whole round is ONE dispatch + ONE jit-cache hit instead of
        ``n_local`` of each.

        ``masked=True`` builds the elastic-drop variant ``(state, batches,
        mask) -> ...``: EVERY worker still runs the local block (a dropped
        worker was computing, not absent), but the round-ending sync runs
        with ``drop`` semantics — workers masked out neither contribute to
        nor receive the aggregate (see :meth:`_apply_event`).  One compiled
        function per Round serves every mask value (the mask is a traced
        argument)."""
        local_update = self.plan.local_update_fn()
        vupdate = jax.vmap(local_update)
        row_fn = self._probe_row_fn(rnd.event)
        if masked:
            assert rnd.event is not None, \
                "a masked round needs a sync event to drop workers from"

        def round_fn(state: HSGDState, batches, mask=None):
            """batches: a length-``n_local`` tuple of per-step batches."""
            stacked = _stack_batches(rnd.n_local, batches)

            def body(carry, batch):
                params, opt_state = carry
                params, opt_state, metrics = vupdate(params, opt_state, batch)
                return (params, opt_state), jax.tree.map(
                    lambda m: m.mean(), metrics)

            (params, opt_state), metrics = jax.lax.scan(
                body, (state.params, state.opt_state), stacked)
            cstate, mbuf = state.comms, state.metrics
            if rnd.event is not None:
                if row_fn is not None and mbuf is not None:
                    mbuf = mbuf.push(row_fn(params))
                params, opt_state, cstate = self._apply_event(
                    params, opt_state, cstate, rnd.event,
                    mask=mask, drop=masked)
            state = HSGDState(params, opt_state, state.step + rnd.n_local,
                              cstate, mbuf)
            return state, metrics  # metrics stacked (n_local,) per entry

        if not self.plan._jit:
            return round_fn
        if masked:
            return jax.jit(round_fn, donate_argnums=0)
        return jax.jit(lambda s, b: round_fn(s, b), donate_argnums=0)


# ---------------------------------------------------------------------------
# mesh: shard_map + named-axis collectives (the deployment path)
# ---------------------------------------------------------------------------
class MeshExecutor(Executor):
    """One worker per replica-mesh coordinate; sync events ARE named-axis
    all-reduces.

    mesh: for a uniform hierarchy, a mesh whose replica axes (everything but
    'model') mirror the hierarchy's ``group_sizes`` outermost-first — build
    one with ``launch.mesh.make_hsgd_mesh(spec.group_sizes)`` /
    ``make_host_mesh(group_sizes=...)``; a ``GroupedTopology`` has no
    per-level axis structure, so any replica layout with
    ``n_replicas(mesh) == topology.n`` works (events lower over the FLAT
    worker axis with one-hot membership weights — see
    ``GroupedTopology.shard_aggregate``).  None auto-builds the matching
    mesh from the bound topology (needs prod(group_sizes) / n devices).
    Params are placed ``P(('pod','data'), ...)`` so the level-ℓ mean is an
    all-reduce over exactly the mesh axes of levels >= ℓ.

    **Masked-round contract** (parity with ``SimExecutor``): runtime
    participation masks thread into the round core as a per-worker weight
    on the collective.  ``step_fn(event, masked=True)`` is the Algorithm-1
    semantics — a masked-out worker contributes nothing but still RECEIVES
    the aggregate (and keeps its unconsumed comms residual);
    ``round_fn(rnd, masked=True)`` is the elastic-deadline semantics — a
    dropped worker still runs its local updates but neither contributes to
    nor receives the aggregate, keeping its exact post-update params, opt
    state AND unconsumed comms residuals.  Elastic runtime policies
    therefore run on this backend too (``HSGD(..., executor='mesh',
    runtime=RuntimeModel(policy=...))``).

    exact: replay the ENTIRE sim reduce per shard — all_gather the full
    worker block and run ``topology.aggregate`` on it (identical input
    shape, identical reduce axes, identical weight combination), each shard
    then selecting its own row — instead of the production pmean/psum
    lowering.  Bit-identical to the SimExecutor trajectory for every
    topology (uniform AND grouped), every event (full, partial-group,
    masked, dropped) and every codec, at n_workers x the sync bytes.
    Verification mode; the default lowering matches sim to
    accumulation-dtype rounding (tested)."""

    def __init__(self, mesh=None, *, exact: bool = False):
        super().__init__()
        self.mesh = mesh
        self.exact = exact
        self.rep_axes = None

    def twin(self) -> "MeshExecutor":
        return MeshExecutor(mesh=self.mesh, exact=self.exact)

    def _validate(self) -> None:
        from repro.launch.mesh import (make_hsgd_mesh, n_replicas,
                                       replica_axes)
        topo = self.plan.topology
        spec = getattr(topo, "spec", None)
        if self.mesh is None:
            self.mesh = make_hsgd_mesh(
                spec.group_sizes if spec is not None else (topo.n,))
        self.rep_axes = replica_axes(self.mesh)
        sizes = tuple(self.mesh.shape[a] for a in self.rep_axes)
        if spec is not None:
            if sizes != tuple(spec.group_sizes):
                raise ValueError(
                    f"mesh replica axes {dict(zip(self.rep_axes, sizes))} "
                    f"do not mirror the hierarchy levels "
                    f"{spec.group_sizes}; build the mesh with "
                    f"make_hsgd_mesh(spec.group_sizes)")
        elif n_replicas(self.mesh) != topo.n:
            raise ValueError(
                f"{type(topo).__name__} lowers over the flat worker axis: "
                f"need n_replicas(mesh) == {topo.n} workers, got "
                f"{n_replicas(self.mesh)} "
                f"({dict(zip(self.rep_axes, sizes))})")
        if spec is None and self.plan.metrics is not None \
                and self.plan.metrics.divergences:
            raise NotImplementedError(
                f"{type(topo).__name__} has no named-axis level structure "
                "for the in-graph divergence probe; run it on the simulator "
                "(HSGD(..., executor='sim')) or disable divergence probing "
                "(metrics=Metrics(divergences=False))")

    def place(self, state: HSGDState) -> HSGDState:
        from repro.launch.partitioning import hsgd_state_shardings
        return jax.device_put(state, hsgd_state_shardings(self.mesh, state))

    # -- spec helpers -------------------------------------------------------
    def _lead_spec(self, ndim: int, lead_axis: int = 0) -> P:
        """Worker axis over all replica mesh axes, other dims replicated
        (shared definition with the device-placement shardings)."""
        from repro.launch.partitioning import worker_axis_spec
        return worker_axis_spec(self.rep_axes, ndim, lead_axis)

    # -- the per-shard sync body (shared: round core + analysis trace) ------
    def _event_applier(self, event: SyncEvent, drop: bool = False):
        """Per-shard sync body for one event: ``(params, opt_state, cstate,
        mask, widx) -> (params, opt_state, cstate)``.  Extracted from the
        round core so :meth:`sync_fn` can wrap the IDENTICAL closure in its
        own shard_map — the audited sync program and the round body can
        never drift apart."""
        plan, rep = self.plan, self.rep_axes
        topo = plan.topology
        acc = topo.aggregator.accum_dtype
        wvec = topo._event_weights(event, None)
        part = topo.participants(event)
        wire_ok = _wire_eligible(plan, event)
        if wire_ok:
            ev_axes = tuple(topo.level_axes(event, rep))
            members = math.prod(self.mesh.shape[a] for a in ev_axes)

        def apply_event(params, opt_state, cstate, mask, widx):
            wire = None
            if wire_ok:
                # exact mode replays the SIM wire arithmetic on the gathered
                # block (bitwise vs SimExecutor); production lowers the
                # codec's collective over exactly the event's mesh axes
                wire = ExactWireOps(rep, widx, topo.spec.group_sizes,
                                    event.level, mask) if self.exact else \
                    MeshWireOps(ev_axes, members, mask, widx)
            if self.exact:
                # replay the ENTIRE sim reduce on the gathered worker block
                # (same shapes, same weight combination -> bitwise), then
                # select this shard's own row
                def reduce_fn(tree):
                    g = jax.tree.map(
                        lambda x: jax.lax.all_gather(x, rep, axis=0,
                                                     tiled=True), tree)
                    out = topo.aggregate(g, event, mask=mask)
                    return jax.tree.map(
                        lambda x: jax.lax.dynamic_index_in_dim(
                            x, widx, axis=0, keepdims=True), out)
            else:
                w = None if mask is None else mask.astype(acc)[widx]
                if wvec is not None:
                    ws = jnp.asarray(wvec)[widx]
                    w = ws if w is None else w * ws
                one = lambda x: topo.shard_aggregate(
                    x, rep, event, worker_index=widx, weight=w)
                reduce_fn = lambda tree: jax.tree.map(one, tree)
            new_p, new_o, new_c = _apply_sync(plan, reduce_fn, params,
                                              opt_state, cstate, wire=wire)
            if plan.comms is not None:
                # same restores as SimExecutor._apply_event, per shard: the
                # comms path hands the reduce codec-roundtripped payloads,
                # so workers a partial-group event did not sync get their
                # true state back, and a masked-out worker's error-feedback
                # residual is not consumed
                if part is not None:
                    keep = jnp.asarray(part)[widx]
                    new_p = _keep_shard(keep, new_p, params)
                    new_o = _keep_shard(keep, new_o, opt_state)
                    if cstate is not None:
                        new_c = _keep_shard(keep, new_c, cstate)
                if mask is not None and cstate is not None:
                    new_c = _keep_shard(mask.astype(bool)[widx], new_c,
                                        cstate)
            if drop:
                keep = mask.astype(bool)[widx]
                new_p = _keep_shard(keep, new_p, params)
                new_o = _keep_shard(keep, new_o, opt_state)
                if cstate is not None:
                    new_c = _keep_shard(keep, new_c, cstate)
            return new_p, new_o, new_c

        return apply_event

    def sync_fn(self, event: SyncEvent):
        plan, mesh, rep = self.plan, self.mesh, self.rep_axes
        sizes = tuple(mesh.shape[a] for a in rep)
        applier = self._event_applier(event)

        def shard_body(params, opt_state, cstate, mask):
            widx = flat_worker_index(rep, sizes)
            return applier(params, opt_state, cstate, mask, widx)

        def sync(params, opt_state, cstate, mask=None):
            pspec = jax.tree.map(lambda x: self._lead_spec(x.ndim), params)
            ospec = jax.tree.map(lambda x: self._lead_spec(x.ndim), opt_state)
            cspec = jax.tree.map(lambda x: self._lead_spec(x.ndim), cstate)
            # same check_rep policy as the round core (see _round_core)
            kw = dict(check_rep=False) \
                if (plan.comms is not None or mask is not None) else {}
            if mask is None:
                fn = shard_map(lambda p, o, c: shard_body(p, o, c, None),
                               mesh=mesh, in_specs=(pspec, ospec, cspec),
                               out_specs=(pspec, ospec, cspec), **kw)
                return fn(params, opt_state, cstate)
            fn = shard_map(lambda p, o, c, m: shard_body(p, o, c, m),
                           mesh=mesh, in_specs=(pspec, ospec, cspec, P()),
                           out_specs=(pspec, ospec, cspec), **kw)
            return fn(params, opt_state, cstate, jnp.asarray(mask))

        return sync

    # -- the shard_mapped round body ----------------------------------------
    def _round_core(self, event: Optional[SyncEvent], masked: bool = False,
                    drop: bool = False):
        """(params, opt_state, comms_state, stacked_batches[, mask]) ->
        (params, opt_state, comms_state, metrics) with the local scan and
        the event collective under one shard_map; each shard holds exactly
        one worker.  The round length is carried by the stacked batch's
        leading axis.

        With a comms plan bound, each shard fuses its ``(1, ...)`` leaves
        into flat per-dtype buffers, codec-roundtrips them (error-feedback
        residuals are sharded like params), and the named-axis collective
        runs once per BUFFER — O(dtypes) pmeans per sync in the lowered
        program instead of O(leaves).

        ``masked=True`` threads a replicated (n,) runtime mask into the
        body; each shard folds its own mask entry into the collective's
        weight (mirroring ``Topology._event_weights``) and row-selects its
        state afterwards.  ``drop`` picks between the two mask semantics —
        see the class docstring.

        With metrics on, the probe buffer rides through the shard_map
        REPLICATED (``P()`` in and out): the divergence row is the
        named-axis probe (:meth:`~repro.obs.Metrics.mesh_row_fn` — per-level
        pmean group means, one final stacked pmean, so the pushed values are
        identical on every shard), measured on the pre-aggregation shard
        params right before the event collective."""
        plan, mesh, rep = self.plan, self.mesh, self.rep_axes
        vupdate = jax.vmap(plan.local_update_fn())
        sizes = tuple(mesh.shape[a] for a in rep)
        apply_event = self._event_applier(event, drop=drop) \
            if event is not None else None
        row_fn = None
        if event is not None and plan.metrics is not None \
                and plan.metrics.divergences:
            row_fn = plan.metrics.mesh_row_fn(plan.topology, rep)

        def body(params, opt_state, cstate, mbuf, stacked, mask):
            # per-shard shapes: leading worker axis == 1
            def local_block(carry, batch):
                p, o = carry
                p, o, metrics = vupdate(p, o, batch)
                return (p, o), jax.tree.map(lambda m: m.mean(), metrics)

            (p0, o0) = params, opt_state
            (params, opt_state), metrics = jax.lax.scan(
                local_block, (params, opt_state), stacked)
            widx = flat_worker_index(rep, sizes)
            if masked and not drop:
                # Algorithm-1 masked step: a non-participating worker never
                # ran its update (it still receives the aggregate below)
                keep = mask.astype(bool)[widx]
                params = _keep_shard(keep, params, p0)
                opt_state = _keep_shard(keep, opt_state, o0)
            if event is not None:
                if row_fn is not None and mbuf is not None:
                    mbuf = mbuf.push(row_fn(params))
                params, opt_state, cstate = apply_event(
                    params, opt_state, cstate,
                    mask if masked else None, widx)
            # worker-mean of the per-step metrics, replicated everywhere
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, rep), metrics)
            return params, opt_state, cstate, mbuf, metrics

        def core(params, opt_state, cstate, mbuf, stacked, mask=None):
            pspec = jax.tree.map(lambda x: self._lead_spec(x.ndim), params)
            ospec = jax.tree.map(lambda x: self._lead_spec(x.ndim), opt_state)
            cspec = jax.tree.map(lambda x: self._lead_spec(x.ndim), cstate)
            mspec = jax.tree.map(lambda x: P(), mbuf)
            bspec = jax.tree.map(lambda x: self._lead_spec(x.ndim, 1), stacked)
            # pallas_call (the comms codec kernels) has no shard_map
            # replication rule, masked rounds mix per-shard row-selects
            # into the collective outputs, and the probe pushes partially-
            # replicated pmeans into the replicated buffer; the aggregates
            # (and the probe row — its last op is a pmean over ALL replica
            # axes) are replicated by construction, so skipping the check
            # is safe
            kw = dict(check_rep=False) \
                if (plan.comms is not None or masked
                    or row_fn is not None) else {}
            if not masked:
                fn = shard_map(
                    lambda p, o, c, mb, b: body(p, o, c, mb, b, None),
                    mesh=mesh, in_specs=(pspec, ospec, cspec, mspec, bspec),
                    out_specs=(pspec, ospec, cspec, mspec, P()), **kw)
                return fn(params, opt_state, cstate, mbuf, stacked)
            # the mask rides in replicated: every shard reads its own entry
            fn = shard_map(
                lambda p, o, c, mb, b, m: body(p, o, c, mb, b, m), mesh=mesh,
                in_specs=(pspec, ospec, cspec, mspec, bspec, P()),
                out_specs=(pspec, ospec, cspec, mspec, P()), **kw)
            return fn(params, opt_state, cstate, mbuf, stacked, mask)

        return core

    # -- compiled entry points ----------------------------------------------
    def _build_step(self, event: Optional[SyncEvent], masked: bool = False):
        # Algorithm-1 mask semantics when masked (drop=False): see class doc
        core = self._round_core(event, masked=masked)  # fails fast

        def step(state: HSGDState, batch, mask=None):
            args = () if not masked else (jnp.asarray(mask),)
            params, opt_state, cstate, mbuf, metrics = core(
                state.params, state.opt_state, state.comms, state.metrics,
                jax.tree.map(lambda x: x[None], batch), *args)
            metrics = jax.tree.map(lambda m: m[0], metrics)
            return HSGDState(params, opt_state, state.step + 1,
                             cstate, mbuf), metrics

        if not self.plan._jit:
            return step
        return jax.jit(step, donate_argnums=0) if masked else \
            jax.jit(lambda s, b: step(s, b), donate_argnums=0)

    def _build_round(self, rnd: Round, masked: bool = False):
        # elastic-drop mask semantics when masked (drop=True): see class doc
        if masked:
            assert rnd.event is not None, \
                "a masked round needs a sync event to drop workers from"
        core = self._round_core(rnd.event, masked=masked, drop=masked)

        def round_fn(state: HSGDState, batches, mask=None):
            stacked = _stack_batches(rnd.n_local, batches)
            args = () if not masked else (jnp.asarray(mask),)
            params, opt_state, cstate, mbuf, metrics = core(
                state.params, state.opt_state, state.comms, state.metrics,
                stacked, *args)
            state = HSGDState(params, opt_state, state.step + rnd.n_local,
                              cstate, mbuf)
            return state, metrics  # metrics stacked (n_local,) per entry

        if not self.plan._jit:
            return round_fn
        return jax.jit(round_fn, donate_argnums=0) if masked else \
            jax.jit(lambda s, b: round_fn(s, b), donate_argnums=0)


# ---------------------------------------------------------------------------
# registry — the single construction path (mirrors make_topology/aggregator)
# ---------------------------------------------------------------------------
EXECUTORS = {
    "sim": SimExecutor,
    "mesh": MeshExecutor,
}

ExecutorLike = Union[str, Executor, None]


def make_executor(spec: ExecutorLike = None, **kwargs) -> Executor:
    """Resolve an executor from an instance, a registry name, or None
    (-> SimExecutor, the bitwise paper-experiment path)."""
    if isinstance(spec, Executor):
        assert not kwargs, "kwargs only apply when constructing by name"
        return spec
    if spec is None:
        return SimExecutor(**kwargs)
    name = spec.lower()
    if name not in EXECUTORS:
        raise KeyError(f"unknown executor {spec!r}; "
                       f"known: {sorted(EXECUTORS)}")
    return EXECUTORS[name](**kwargs)


def register_executor(name: str, cls) -> None:
    EXECUTORS[name.lower()] = cls
