"""The topology layer: WHICH workers average WHEN — as typed sync events.

The paper's multi-level Algorithm D.1 (and the sandwich analysis) treat the
hierarchy as a *schedule of aggregation events*; this module makes that the
formal contract.  A ``Topology`` answers three questions:

* ``event_at(t)`` / ``schedule(T)`` — the typed ``SyncEvent`` (if any) fired
  after the local update of step ``t``;
* ``aggregate(tree, event)`` — apply the event to a worker-stacked pytree,
  through a pluggable :class:`~repro.core.aggregators.Aggregator` rule;
* ``n`` / ``periods`` — the static shape the engine and planners read.

Two adapters implement it: ``UniformTopology`` (HierarchySpec; reshape-based
means that lower to all-reduces over the matching mesh axes) and
``GroupedTopology`` (explicit possibly-non-uniform Grouping with per-group
periods, Theorem 1's most general setting; (N, n) membership segment-means,
never a dense n x n mixing matrix).  ``make_topology`` is the single
construction path used by launch/, benchmarks/ and the examples.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregators import (Aggregator, AggregatorLike,
                                    axis_weighted_mean, denominator_floor,
                                    make_aggregator, segment_weighted_mean)
from repro.core.grouping import Grouping, contiguous
from repro.core.hierarchy import HierarchySpec, local_sgd, two_level


# ---------------------------------------------------------------------------
# events
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SyncEvent:
    """One aggregation event (replaces the ad-hoc ``("level", l)`` /
    ``("groups", mask)`` step-kind tuples).

    level:  1 = global (paper level 1) ... M = innermost local sync.
    groups: per-group participation for a partial event (heterogeneous
            per-group periods I_i); None = every group at this level.
    weights: optional static per-worker weights for this event (on top of
            the aggregator's own weights and any runtime mask).

    Frozen + tuple fields => hashable, so events key jit caches directly.
    """
    level: int
    groups: Optional[Tuple[bool, ...]] = None
    weights: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        assert self.level >= 1
        if self.groups is not None:
            assert any(self.groups), "an event with no syncing group"


# ---------------------------------------------------------------------------
# protocol
# ---------------------------------------------------------------------------
class Topology(abc.ABC):
    """Formal contract for 'which workers average when, and how'.

    Every topology answers in two forms that the executors keep in
    lockstep: the in-array form (:meth:`aggregate`, the sim reference —
    also what the mesh backend's ``exact=True`` mode replays on an
    all-gathered block for bitwise verification) and the named-axis form
    (:meth:`level_axes` + :meth:`shard_aggregate`, the production mesh
    lowering, equal to the reference up to accumulation-dtype rounding).
    Runtime participation masks enter both forms as per-worker weights:
    a masked-out worker contributes nothing to any mean; whether it
    *receives* the result is the executor's masked-round contract, not
    the topology's."""

    n: int                      # number of workers
    periods: Tuple[int, ...]    # (P_1, ..., P_M), P_1 = G
    aggregator: Aggregator

    @abc.abstractmethod
    def event_at(self, t: int) -> Optional[SyncEvent]:
        """The sync event fired after the update of step ``t`` (0-indexed)."""

    def schedule(self, T: int) -> Tuple[Optional[SyncEvent], ...]:
        """The full event schedule for T steps (static: periods are fixed)."""
        return tuple(self.event_at(t) for t in range(T))

    @abc.abstractmethod
    def aggregate(self, tree, event: SyncEvent, mask=None):
        """Apply ``event`` to a worker-stacked pytree (leading axis n).
        mask (n,) float/bool: runtime partial participation — means run over
        the participating workers only; every member of a syncing group
        receives the result (Algorithm 1 semantics)."""

    # -- mesh lowering ------------------------------------------------------
    def level_axes(self, event: SyncEvent,
                   axis_names: Tuple[str, ...]) -> Tuple[str, ...]:
        """The named mesh axes whose all-reduce realizes ``event``.

        For a uniform hierarchy ``axis_names`` is one replica mesh axis per
        level, outermost (level 1) first, and a level-ℓ event lowers to a
        collective over the axes of levels >= ℓ.  Topologies with no uniform
        level structure (GroupedTopology) lower every event over ALL replica
        axes instead — the flat worker axis — and express the grouping as
        (N, n) one-hot weights inside :meth:`shard_aggregate`."""
        raise NotImplementedError(
            f"{type(self).__name__} does not map onto named mesh axes")

    def shard_aggregate(self, x, axis_names: Tuple[str, ...],
                        event: SyncEvent, *, worker_index,
                        weight=None):
        """Production mesh lowering of ``event`` for ONE worker's shard —
        the named-axis-collective counterpart of :meth:`aggregate`, only
        callable inside ``shard_map``.

        x: this shard's payload (leading worker axis of size 1);
        axis_names: the replica mesh axes (outermost first);
        worker_index: this shard's flat worker index
        (:func:`~repro.core.aggregators.flat_worker_index`);
        weight: this shard's scalar weight — the executor's combination of
        the runtime participation mask and any static per-worker weights
        (None = plain mean).  A zero weight means this worker contributes
        nothing to the collective; what it *keeps* is decided by the
        executor (Algorithm-1 masks receive the aggregate, elastic drops do
        not).  Matches :meth:`aggregate` to accumulation-dtype rounding (the
        collective reduce reassociates); the bitwise path is the executor's
        ``exact=True`` replay."""
        raise NotImplementedError(
            f"{type(self).__name__} has no named-axis lowering; "
            "run it on the simulator (HSGD(..., executor='sim'))")

    # -- participation ------------------------------------------------------
    def participants(self, event: SyncEvent) -> Optional[np.ndarray]:
        """Static (n,) bool: the workers whose state ``event`` replaces, or
        None for all of them.  ``aggregate`` keeps non-participants' rows
        untouched (GroupedTopology partial-group events); alternate sync
        paths (the comms wire) must honor the same contract.

        This is the *static scope* of the :class:`~repro.population.
        Participation` protocol (``event_mask``); :meth:`participation`
        returns the protocol adapter over it."""
        return None

    def participation(self):
        """This topology's static view of the Participation protocol
        (``event_mask == participants``; the dynamic scopes stay open)."""
        from repro.population import StaticParticipation
        return StaticParticipation(self)

    # -- telemetry ----------------------------------------------------------
    def level_groupings(self) -> Dict[int, Grouping]:
        """Worker partition into the level-ℓ subtrees, for every internal
        level ℓ (the per-level divergence telemetry surface).  May be empty
        (single-level schedules have no internal grouping)."""
        return {}

    # -- shared helpers -----------------------------------------------------
    def _event_weights(self, event: SyncEvent, mask) -> Optional[jax.Array]:
        """Combine runtime mask, aggregator weights and event weights into a
        single (n,) weight vector (None = plain mean)."""
        acc = self.aggregator.accum_dtype
        w = None
        for part in (mask, self.aggregator.worker_weights(self.n),
                     None if event.weights is None else np.asarray(event.weights)):
            if part is None:
                continue
            p = jnp.asarray(part).astype(acc)
            w = p if w is None else w * p
        return w


# ---------------------------------------------------------------------------
# uniform multi-level hierarchy
# ---------------------------------------------------------------------------
class UniformTopology(Topology):
    """Uniform multi-level hierarchy (HierarchySpec); reshape-based means.
    Works identically in sim and mesh mode: the level-l mean lowers to an
    all-reduce over exactly the mesh axes of levels >= l."""

    def __init__(self, spec: HierarchySpec, sync_dtype: Optional[str] = None,
                 aggregator: AggregatorLike = None):
        self.spec = spec
        self.n = spec.n_workers
        self.periods = spec.periods
        self.aggregator = make_aggregator(aggregator, sync_dtype=sync_dtype)

    def event_at(self, t: int) -> Optional[SyncEvent]:
        lvl = self.spec.sync_level(t)
        return None if lvl is None else SyncEvent(level=lvl)

    def level_axes(self, event: SyncEvent,
                   axis_names: Tuple[str, ...]) -> Tuple[str, ...]:
        m = self.spec.num_levels
        assert len(axis_names) == m, \
            f"need one replica mesh axis per level, got {axis_names} " \
            f"for {m}-level {self.spec}"
        assert 1 <= event.level <= m, (event, self.spec)
        assert event.groups is None, \
            "uniform hierarchies never emit partial-group events"
        return tuple(axis_names[event.level - 1:])

    def shard_aggregate(self, x, axis_names, event: SyncEvent, *,
                        worker_index, weight=None):
        return self.aggregator.axis_aggregate(
            x, self.level_axes(event, axis_names), weight=weight)

    def level_groupings(self) -> Dict[int, Grouping]:
        return {l: contiguous(self.n, self.spec.n_at_level(l))
                for l in range(1, self.spec.num_levels)}

    def aggregate(self, tree, event: SyncEvent, mask=None):
        gs = self.spec.group_sizes
        m = len(gs)
        assert 1 <= event.level <= m, (event, self.spec)
        assert event.groups is None, \
            "uniform hierarchies have no partial-group events; use " \
            "GroupedTopology or a runtime mask"
        axes = tuple(range(event.level - 1, m))
        agg = self.aggregator
        acc = agg.accum_dtype
        w = self._event_weights(event, mask)

        def per_leaf(x):
            shaped = x.reshape(gs + x.shape[1:])
            wr = None if w is None else \
                w.reshape(gs + (1,) * (shaped.ndim - m))
            payloads = agg.encode(shaped)
            means = {k: axis_weighted_mean(v, wr, axes, acc)
                     for k, v in payloads.items()}
            out = agg.decode(means, shaped)
            return jnp.broadcast_to(out, shaped.shape).reshape(x.shape)

        return jax.tree.map(per_leaf, tree)


# ---------------------------------------------------------------------------
# explicit two-level grouping (Theorem 1's most general setting)
# ---------------------------------------------------------------------------
class GroupedTopology(Topology):
    """Two-level H-SGD with an explicit (possibly non-uniform) Grouping and
    per-group local periods I_i.  Aggregation is an (N, n) membership
    segment-mean — O(N*n) instead of the old dense n x n mixing product.

    Runs on BOTH executors.  Under sim, :meth:`aggregate` is the in-array
    segment-mean; under mesh there is no per-level axis structure to name,
    so every event lowers over the FLAT worker axis (``level_axes`` returns
    all replica axes) and :meth:`shard_aggregate` expresses the membership
    as one-hot weights: each shard contributes ``onehot(group) * w * x`` to
    a single psum of (N, payload) group numerators, then selects its own
    group's mean.  Partial events (``SyncEvent(groups=...)``, heterogeneous
    per-group periods) and runtime masks ride the same form — non-syncing
    groups keep their exact rows, mirroring :meth:`aggregate`; the
    executor's ``exact=True`` mode replays :meth:`aggregate` itself on an
    all-gathered block, so grouped mesh rounds are bitwise-identical to
    sim."""

    def __init__(self, grouping: Grouping, G: int,
                 I: Union[int, Tuple[int, ...]],
                 sync_dtype: Optional[str] = None,
                 aggregator: AggregatorLike = None):
        self.grouping = grouping
        self.n = grouping.n
        self.G = G
        self.I = tuple([I] * grouping.N) if isinstance(I, int) else tuple(I)
        assert len(self.I) == grouping.N
        for Ii in self.I:
            assert G % Ii == 0, (G, Ii)
        self.periods = (G, min(self.I))
        self.aggregator = make_aggregator(aggregator, sync_dtype=sync_dtype)
        self._onehot = np.asarray(grouping.onehot())          # (N, n)
        self._assignment = np.asarray(grouping.assignment)    # (n,)

    def event_at(self, t: int) -> Optional[SyncEvent]:
        if (t + 1) % self.G == 0:
            return SyncEvent(level=1)
        groups = tuple(bool((t + 1) % Ii == 0) for Ii in self.I)
        if not any(groups):
            return None
        if all(groups):
            return SyncEvent(level=2)
        return SyncEvent(level=2, groups=groups)

    def level_groupings(self) -> Dict[int, Grouping]:
        return {1: self.grouping}

    def level_axes(self, event: SyncEvent,
                   axis_names: Tuple[str, ...]) -> Tuple[str, ...]:
        """Flat-worker-axis lowering: a grouped event's collective runs over
        ALL replica axes (the membership lives in :meth:`shard_aggregate`'s
        one-hot weights, not in the mesh shape)."""
        assert event.level in (1, 2), event
        return tuple(axis_names)

    def shard_aggregate(self, x, axis_names, event: SyncEvent, *,
                        worker_index, weight=None):
        """One psum of (N, payload) membership-weighted numerators over the
        flat worker axis; each shard then selects its own group's mean —
        the named-axis form of the (N, n) segment-mean, N x the payload
        bytes of a uniform level's pmean."""
        assert event.level in (1, 2), event
        agg = self.aggregator
        acc = agg.accum_dtype
        N = self.grouping.N
        axes = self.level_axes(event, axis_names)
        if event.level == 1 or event.groups is None:
            syncing = np.ones(N, bool)
        else:
            syncing = np.asarray(event.groups)
        gid = jnp.asarray(self._assignment)[worker_index]     # my group id
        col = jax.nn.one_hot(gid, N, dtype=acc)               # my (N,) column
        w = jnp.asarray(1.0, acc) if weight is None \
            else jnp.asarray(weight, acc).reshape(())
        den = jnp.maximum(jax.lax.psum(col * w, axes),
                          denominator_floor(acc))              # (N,)
        flat = x.reshape(x.shape[0], -1)                      # (1, dim)
        payloads = agg.encode(flat)
        means = {}
        for k, v in payloads.items():
            num = jax.lax.psum(col[:, None] * (v.astype(acc) * w), axes)
            gm = num / den[:, None]                           # (N, dim)
            if event.level == 1:
                # global = unweighted mean of group means (paper A.1)
                gm = jnp.broadcast_to(gm.mean(0, keepdims=True, dtype=acc),
                                      gm.shape)
            means[k] = jax.lax.dynamic_index_in_dim(gm, gid, axis=0,
                                                    keepdims=True)
        out = agg.decode(means, flat)
        keep = jnp.asarray(syncing[self._assignment])[worker_index]
        out = jnp.where(keep, out, flat)
        return out.astype(x.dtype).reshape(x.shape)

    def participants(self, event: SyncEvent) -> Optional[np.ndarray]:
        if event.level == 1 or event.groups is None:
            return None
        return np.asarray(event.groups)[self._assignment]

    def aggregate(self, tree, event: SyncEvent, mask=None):
        assert event.level in (1, 2), event
        agg = self.aggregator
        acc = agg.accum_dtype
        oh = jnp.asarray(self._onehot, acc)
        a = self._assignment
        if event.level == 1 or event.groups is None:
            syncing = np.ones(self.grouping.N, bool)
        else:
            syncing = np.asarray(event.groups)
        sync_workers = jnp.asarray(syncing[a])                 # (n,) bool
        w = self._event_weights(event, mask)
        w = jnp.ones((self.n,), acc) if w is None else w

        def per_leaf(x):
            flat = x.reshape(self.n, -1)
            payloads = agg.encode(flat)
            means = {}
            for k, v in payloads.items():
                gm = segment_weighted_mean(v, w, oh, acc)      # (N, dim)
                if event.level == 1:
                    # global = unweighted mean of group means (paper A.1)
                    gm = jnp.broadcast_to(gm.mean(0, keepdims=True, dtype=acc),
                                          (self.grouping.N, gm.shape[1]))
                means[k] = gm[a]                               # back to (n, dim)
            out = agg.decode(means, flat)
            out = jnp.where(sync_workers[:, None], out, flat)
            return out.astype(x.dtype).reshape(x.shape)

        return jax.tree.map(per_leaf, tree)


# ---------------------------------------------------------------------------
# factory / registry — the single construction path
# ---------------------------------------------------------------------------
TOPOLOGIES = {}


def register_topology(name: str):
    def deco(builder):
        TOPOLOGIES[name.lower()] = builder
        return builder
    return deco


@register_topology("uniform")
def _build_uniform(*, spec: Optional[HierarchySpec] = None,
                   group_sizes=None, periods=None, **kw) -> UniformTopology:
    if spec is None:
        assert group_sizes is not None and periods is not None, \
            "uniform topology needs spec= or group_sizes=/periods="
        spec = HierarchySpec(tuple(group_sizes), tuple(periods))
    return UniformTopology(spec, **kw)


@register_topology("two_level")
def _build_two_level(*, n: int, N: int, G: int, I: int, **kw):
    return UniformTopology(two_level(n, N, G, I), **kw)


@register_topology("local_sgd")
def _build_local_sgd(*, n: int, P: int, **kw):
    return UniformTopology(local_sgd(n, P), **kw)


@register_topology("grouped")
def _build_grouped(*, grouping: Grouping, G: int, I, **kw):
    return GroupedTopology(grouping, G, I, **kw)


def make_topology(kind: Union[str, HierarchySpec, Grouping], **kwargs) -> Topology:
    """Build a topology by registry name.

        make_topology("uniform", spec=HierarchySpec((2, 4), (8, 2)))
        make_topology("two_level", n=8, N=2, G=8, I=2, sync_dtype="bfloat16")
        make_topology("grouped", grouping=g, G=8, I=(2, 4), aggregator="sign")

    ``aggregator`` accepts an Aggregator instance or a registry name
    ("mean" | "compressed"/"bf16" | "weighted" | "sign"); the legacy
    ``sync_dtype`` flag maps to the compressed aggregator.  As a
    convenience, passing a HierarchySpec or Grouping as ``kind`` routes to
    the matching builder."""
    if isinstance(kind, HierarchySpec):
        return _build_uniform(spec=kind, **kwargs)
    if isinstance(kind, Grouping):
        return _build_grouped(grouping=kind, **kwargs)
    name = kind.lower()
    if name not in TOPOLOGIES:
        raise KeyError(f"unknown topology {kind!r}; known: {sorted(TOPOLOGIES)}")
    return TOPOLOGIES[name](**kwargs)
