"""Two-level worker groupings (paper §3, §4.3 and the Fig. 3c constructions).

A ``Grouping`` is an explicit assignment of n workers to N groups (possibly
non-uniform, as Theorem 1 allows). The paper's aggregation semantics
(Algorithm 1) as a mixing matrix:
  local  A_loc[j, j'] = 1/n_i   if j, j' in the same group V_i
  global A_glob[j, j'] = (1/N) * 1/n_{i(j')}   (unweighted mean of group means)
Appendix A.1's spectral claim (eigenvalue 1 with multiplicity N for A_loc) is
verified in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Grouping:
    assignment: tuple  # length n, group ids 0..N-1

    def __post_init__(self):
        a = np.asarray(self.assignment)
        assert a.ndim == 1 and a.min() >= 0
        ids = np.unique(a)
        assert (ids == np.arange(len(ids))).all(), "group ids must be dense"

    @classmethod
    def from_labels(cls, labels) -> "Grouping":
        """Grouping from arbitrary per-worker labels (dense-relabelled in
        order of first appearance).  This is how a population draw becomes
        a Theorem-2 regrouping: label each sampled slot with its drawn cell
        id and the round's random assignment of population members to
        groups falls out (``Draw.grouping`` does exactly this)."""
        labels = np.asarray(labels)
        assert labels.ndim == 1 and len(labels) > 0, labels.shape
        _, ids = np.unique(labels, return_inverse=True)
        first = {}
        dense = np.empty(len(labels), np.int64)
        for j, g in enumerate(ids):
            dense[j] = first.setdefault(int(g), len(first))
        return cls(tuple(dense))

    @property
    def n(self) -> int:
        return len(self.assignment)

    @property
    def N(self) -> int:
        return int(max(self.assignment)) + 1

    @property
    def sizes(self) -> np.ndarray:
        return np.bincount(np.asarray(self.assignment), minlength=self.N)

    def members(self, i: int) -> np.ndarray:
        return np.nonzero(np.asarray(self.assignment) == i)[0]

    # -- mixing matrices (paper Appendix A.1) --------------------------------
    def local_matrix(self) -> np.ndarray:
        a = np.asarray(self.assignment)
        same = a[:, None] == a[None, :]
        return same / self.sizes[a][None, :].T  # row j: 1/n_{i(j)} over V_{i(j)}

    def global_matrix(self) -> np.ndarray:
        a = np.asarray(self.assignment)
        w = 1.0 / (self.N * self.sizes[a])     # each worker j' weighted 1/(N n_i(j'))
        return np.tile(w[None, :], (self.n, 1))

    def onehot(self) -> np.ndarray:
        """(N, n) membership indicator."""
        a = np.asarray(self.assignment)
        return (np.arange(self.N)[:, None] == a[None, :]).astype(np.float64)

    def size_weights(self) -> np.ndarray:
        """(n,) weights proportional to each worker's group size inverse, so a
        weighted GLOBAL mean over workers equals the unweighted mean of group
        means (pairs with ``WeightedAggregator`` for FedAvg-style runs)."""
        return 1.0 / (self.N * self.sizes[np.asarray(self.assignment)])


def contiguous(n: int, N: int) -> Grouping:
    assert n % N == 0
    k = n // N
    return Grouping(tuple(j // k for j in range(n)))


def random_grouping(n: int, N: int, seed: int) -> Grouping:
    """Uniform random equal-size grouping (the paper's S)."""
    assert n % N == 0
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    a = np.empty(n, np.int64)
    a[perm] = np.arange(n) // (n // N)
    return Grouping(tuple(a))


def group_iid(labels: Sequence[int], N: int) -> Grouping:
    """Spread each label across groups round-robin => upward divergence ~ 0
    (the paper's 'group-IID' construction, Fig. 3c)."""
    labels = np.asarray(labels)
    order = np.argsort(labels, kind="stable")
    a = np.empty(len(labels), np.int64)
    a[order] = np.arange(len(labels)) % N
    return Grouping(tuple(a))


def diversity_grouping(grads: np.ndarray, N: int) -> Grouping:
    """Operationalize Remark 2: pick the grouping with the SMALLEST upward
    divergence by making each group internally diverse.

    grads: (n, dim) per-worker gradients at a common point. Greedy balanced
    assignment: workers sorted by distance from the global mean (farthest
    first) go round-robin-by-need to the group whose running mean is pulled
    closest to the global mean by accepting them."""
    g = np.asarray(grads, np.float64)
    n, dim = g.shape
    assert n % N == 0
    k = n // N
    gbar = g.mean(0)
    order = np.argsort(-np.linalg.norm(g - gbar, axis=1))  # farthest first
    sums = np.zeros((N, dim))
    counts = np.zeros(N, np.int64)
    assign = np.empty(n, np.int64)
    for j in order:
        best, best_cost = None, None
        for i in range(N):
            if counts[i] >= k:
                continue
            mean_i = (sums[i] + g[j]) / (counts[i] + 1)
            cost = float(np.linalg.norm(mean_i - gbar))
            if best is None or cost < best_cost:
                best, best_cost = i, cost
        assign[j] = best
        sums[best] += g[j]
        counts[best] += 1
    return Grouping(tuple(assign))


def sample_participation(grouping_or_sizes, frac: float, seed: int) -> np.ndarray:
    """Uniform per-group worker sampling (paper Appendix E partial
    participation): each group contributes max(1, round(frac * n_i))
    participants.  Returns a bool (n,) mask."""
    if isinstance(grouping_or_sizes, Grouping):
        groups = [grouping_or_sizes.members(i)
                  for i in range(grouping_or_sizes.N)]
        n = grouping_or_sizes.n
    else:  # uniform hierarchy: tuple of (N, K) -> contiguous groups
        N, K = grouping_or_sizes
        groups = [np.arange(i * K, (i + 1) * K) for i in range(N)]
        n = N * K
    rng = np.random.default_rng(seed)
    mask = np.zeros(n, bool)
    for members in groups:
        k = max(1, int(round(frac * len(members))))
        mask[rng.choice(members, size=k, replace=False)] = True
    return mask


def group_noniid(labels: Sequence[int], N: int) -> Grouping:
    """Pack similar labels into the same group => large upward divergence
    (the paper's 'group-non-IID' construction)."""
    labels = np.asarray(labels)
    n = len(labels)
    assert n % N == 0
    order = np.argsort(labels, kind="stable")
    a = np.empty(n, np.int64)
    a[order] = np.arange(n) // (n // N)
    return Grouping(tuple(a))
