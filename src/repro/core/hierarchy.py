"""Hierarchy specification for multi-level H-SGD (paper Algorithm 1 / D.1).

Levels are 1-indexed as in the paper: level 1 is the *global* aggregation
(period ``P_1 = G``), level M the innermost local aggregation
(period ``P_M``, the two-level ``I``).  A level-ℓ aggregation averages worker
models over index positions ℓ..M of the worker path (k_1, ..., k_M) — i.e.
within each level-(ℓ-1) server's subtree — and the *highest* matching level
wins at any step (the ``break`` in Algorithm D.1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class HierarchySpec:
    """Uniform multi-level hierarchy: server at level ℓ-1 has N_ℓ children.

    group_sizes: (N_1, ..., N_M)  — n = prod(group_sizes) workers.
    periods:     (P_1, ..., P_M)  — P_1 > P_2 > ... > P_M >= 1,
                                    P_{ℓ+1} divides P_ℓ.
    Two-level H-SGD(G, I, N groups of K): group_sizes=(N, K), periods=(G, I).
    Local SGD with period P: group_sizes=(n,), periods=(P,).
    """
    group_sizes: Tuple[int, ...]
    periods: Tuple[int, ...]

    def __post_init__(self):
        assert len(self.group_sizes) == len(self.periods) >= 1
        for a, b in zip(self.periods, self.periods[1:]):
            assert a >= b and a % b == 0, \
                f"periods must be nested multiples, got {self.periods}"
        assert all(s >= 1 for s in self.group_sizes)
        assert all(p >= 1 for p in self.periods)

    # -- structure ----------------------------------------------------------
    @property
    def num_levels(self) -> int:
        return len(self.group_sizes)

    @property
    def n_workers(self) -> int:
        return int(np.prod(self.group_sizes))

    @property
    def G(self) -> int:
        return self.periods[0]

    @property
    def I(self) -> int:
        return self.periods[-1]

    def n_at_level(self, level: int) -> int:
        """n_ℓ = prod_{j<=ℓ} N_j — number of level-ℓ subtrees (paper's n_ℓ)."""
        return int(np.prod(self.group_sizes[:level]))

    # -- schedule -------------------------------------------------------------
    def sync_level(self, t: int) -> Optional[int]:
        """Aggregation level after the update of step ``t`` (0-indexed):
        the smallest ℓ (highest level) with P_ℓ | t+1, else None."""
        for lvl, p in enumerate(self.periods, start=1):
            if (t + 1) % p == 0:
                return lvl
        return None

    def schedule(self, T: int) -> Tuple[Optional[int], ...]:
        return tuple(self.sync_level(t) for t in range(T))

    def sync_counts(self, T: int) -> Tuple[int, ...]:
        """Number of level-ℓ events in T steps, ℓ = 1..M (the break
        semantics make these disjoint: a level-1 step is NOT also counted
        at level 2) — the input to communication-cost models."""
        counts = [0] * self.num_levels
        for t in range(T):
            lvl = self.sync_level(t)
            if lvl is not None:
                counts[lvl - 1] += 1
        return tuple(counts)


def two_level(n: int, N: int, G: int, I: int) -> HierarchySpec:
    assert n % N == 0, (n, N)
    return HierarchySpec(group_sizes=(N, n // N), periods=(G, I))


def local_sgd(n: int, P: int) -> HierarchySpec:
    return HierarchySpec(group_sizes=(n,), periods=(P,))
