"""Deployment planner: turn Theorem 2 into an actionable (N, G, I) choice.

The paper's conclusion promises "valuable insights into the design of
practical H-SGD systems, including the choice of global and local
aggregation periods".  This module makes that concrete: given the problem
constants (L, sigma^2, eps~^2, f0-f*), the fleet (n workers, valid group
counts), a training horizon T and a communication-cost model (seconds per
local / global aggregation round + per-step compute), enumerate the valid
(N, G, I) grid and return the configuration minimizing the Theorem-2 bound
subject to a wall-clock budget — or minimizing wall-clock subject to a bound
target.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import theory


@dataclasses.dataclass(frozen=True)
class CommModel:
    """Seconds per event (paper Table E.1 measured near/far rounds)."""
    compute_s: float          # one local SGD iteration
    local_round_s: float      # one intra-group aggregation (near)
    global_round_s: float     # one global aggregation (far)

    def wall_clock(self, T: int, G: int, I: int) -> float:
        n_glob = T // G
        n_loc = T // I - n_glob   # local rounds subsumed by global ones
        return T * self.compute_s + n_loc * self.local_round_s \
            + n_glob * self.global_round_s

    @classmethod
    def fit_from_trace(cls, history: Sequence[Dict],
                       topology) -> "CommModel":
        """Least-squares fit of the three constants from a simulated (or
        measured) run: ``history`` is :meth:`repro.core.HSGD.run_rounds`
        output whose records carry ``sim_time_s`` (any trace with ``t`` +
        cumulative seconds works), ``topology`` the
        :class:`~repro.core.topology.Topology` (or anything with
        ``schedule(T)``) that produced it.  Each record contributes one
        equation  ``time(t) ~= t*compute + n_loc(t)*local + n_glob(t)*
        global``  with the event counts read off the schedule (levels >= 2
        lumped as "local", level 1 as "global"); the solution is clipped at
        zero.  This closes the loop runtime -> planner: simulate a regime
        once, fit, then :func:`enumerate_plans` prices every (N, G, I)
        under it."""
        import numpy as np
        recs = [r for r in history if "sim_time_s" in r]
        assert recs, "no record carries sim_time_s — run with a runtime " \
                     "model (HSGD(..., runtime=RuntimeModel(...)))"
        T = max(int(r["t"]) for r in recs)
        # the clock restarts at 0 on every run_rounds call, while record t
        # is absolute — a resumed trace starts at t0 > 0, so regress steps
        # and event counts RELATIVE to the trace's own start, not step 0
        t0 = min(int(r["t"]) for r in recs) - 1
        sched = topology.schedule(T)
        # Topology.schedule yields SyncEvents, HierarchySpec.schedule ints
        lvls = [ev if ev is None or isinstance(ev, int) else ev.level
                for ev in sched]
        n_loc = np.cumsum([l is not None and l >= 2 for l in lvls])
        n_glob = np.cumsum([l == 1 for l in lvls])
        loc0 = n_loc[t0 - 1] if t0 else 0
        glob0 = n_glob[t0 - 1] if t0 else 0
        A = np.array([[r["t"] - t0,
                       n_loc[int(r["t"]) - 1] - loc0,
                       n_glob[int(r["t"]) - 1] - glob0]
                      for r in recs], float)
        y = np.array([r["sim_time_s"] for r in recs], float)
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        c, lo, gl = (max(float(v), 0.0) for v in coef)
        return cls(compute_s=c, local_round_s=lo, global_round_s=gl)


@dataclasses.dataclass(frozen=True)
class PlanPoint:
    N: int
    G: int
    I: int
    bound: float
    wall_s: float
    gamma: float


def enumerate_plans(*, n: int, T: int, L: float, sigma2: float,
                    eps_tilde2: float, f0_minus_fstar: float,
                    comm: CommModel,
                    Gs: Sequence[int] = (8, 16, 32, 64, 128, 256),
                    Is: Sequence[int] = (1, 2, 4, 8, 16, 32),
                    Ns: Optional[Sequence[int]] = None) -> List[PlanPoint]:
    if Ns is None:
        Ns = [N for N in range(2, n) if n % N == 0]
    out = []
    for N in Ns:
        for G in Gs:
            for I in Is:
                if I > G or G % I:
                    continue
                gamma = 0.9 * theory.lr_cap(G, L)
                b = theory.theorem2_bound(
                    gamma=gamma, T=T, L=L, sigma2=sigma2,
                    f0_minus_fstar=f0_minus_fstar, n=n, N=N, G=G, I=I,
                    eps_tilde2=eps_tilde2)
                out.append(PlanPoint(N, G, I, b, comm.wall_clock(T, G, I),
                                     gamma))
    return out


def best_under_budget(plans: Sequence[PlanPoint],
                      wall_budget_s: float) -> Optional[PlanPoint]:
    """Tightest bound among plans meeting the wall-clock budget."""
    ok = [p for p in plans if p.wall_s <= wall_budget_s]
    return min(ok, key=lambda p: p.bound) if ok else None


def fastest_under_bound(plans: Sequence[PlanPoint],
                        bound_target: float) -> Optional[PlanPoint]:
    """Cheapest wall-clock among plans meeting a bound target."""
    ok = [p for p in plans if p.bound <= bound_target]
    return min(ok, key=lambda p: p.wall_s) if ok else None


def pareto_front(plans: Sequence[PlanPoint]) -> List[PlanPoint]:
    """(wall_s, bound) Pareto-efficient plans, sorted by wall_s."""
    pts = sorted(plans, key=lambda p: (p.wall_s, p.bound))
    front: List[PlanPoint] = []
    best = math.inf
    for p in pts:
        if p.bound < best - 1e-15:
            front.append(p)
            best = p.bound
    return front
