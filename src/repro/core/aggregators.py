"""Pluggable aggregation rules for H-SGD sync events.

The paper's Algorithm 1 aggregates by the plain mean; related work makes the
*rule* a first-class object (signSGD's majority vote, compressed payloads).
An ``Aggregator`` factors every rule into two pure leaf-level hooks around
the one collective a topology knows how to do — a weighted mean:

    payloads = agg.encode(x)          # dict of arrays shaped like x
    means    = {k: weighted_mean(v) for k, v in payloads.items()}
    new_x    = agg.decode(means, x)   # back to x.dtype

The mean itself comes in two forms, both driving the SAME hooks so a rule
written once works everywhere:

* segment form — in-array means over a worker axis (reshape-mean for the
  uniform hierarchy, membership-matrix segment-mean for arbitrary groupings);
  this is what the sim executor runs on a single device;
* axis-collective form (:meth:`Aggregator.axis_aggregate`) — ``lax.pmean`` /
  ``lax.psum`` over *named mesh axes* inside ``shard_map``; this is what the
  mesh executor lowers each sync event to, so the level-ℓ mean becomes an
  all-reduce over exactly the mesh axes of levels >= ℓ.

``accum_dtype`` pins the accumulation/payload dtype, which is what the
collective actually moves on a mesh (bf16 halves the sync bytes — measured
in §Perf).
"""
from __future__ import annotations

import abc
from typing import Any, Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np


class Aggregator(abc.ABC):
    """A sync rule: encode worker payloads, mean them, decode the result.

    accum_dtype is both the payload dtype (collective bytes) and the
    accumulation dtype of the mean."""

    accum_dtype = jnp.float32

    def encode(self, x: jax.Array) -> Dict[str, jax.Array]:
        return {"value": x.astype(self.accum_dtype)}

    def decode(self, means: Dict[str, jax.Array], like: jax.Array) -> jax.Array:
        return means["value"].astype(like.dtype)

    def worker_weights(self, n: int) -> Optional[np.ndarray]:
        """Optional static per-worker weights, multiplied into the
        participation mask by the topology."""
        return None

    def axis_aggregate(self, x: jax.Array, axis_names,
                       weight: Optional[jax.Array] = None) -> jax.Array:
        """Axis-collective form: the same encode/mean/decode contract, but
        the mean is a ``pmean``/``psum`` over the named mesh axes of the
        syncing levels.  Only callable inside ``shard_map``; ``weight`` is
        this shard's (scalar) worker weight, or None for a plain mean."""
        payloads = self.encode(x)
        means = {k: named_axis_weighted_mean(v, weight, axis_names,
                                             self.accum_dtype)
                 for k, v in payloads.items()}
        return self.decode(means, x)

class MeanAggregator(Aggregator):
    """Exact paper semantics: f32 mean of the participating workers."""

    def __init__(self, dtype: str = "float32"):
        self.accum_dtype = jnp.dtype(dtype)

    def __repr__(self):
        return f"MeanAggregator({self.accum_dtype.name})"


class CompressedAggregator(MeanAggregator):
    """Mean with a compressed payload (default bf16): halves the collective
    bytes of every sync — the beyond-paper §Perf switch, now available to
    every topology rather than a Uniform-only flag."""

    def __init__(self, dtype: str = "bfloat16"):
        super().__init__(dtype)

    def __repr__(self):
        return f"CompressedAggregator({self.accum_dtype.name})"


class WeightedAggregator(Aggregator):
    """Weighted mean with fixed per-worker weights (e.g. dataset-size
    proportional FedAvg weights, or importance weights under partial
    participation).  Weights multiply the participation mask, so a masked
    sync means over ``mask * weights``."""

    def __init__(self, weights, dtype: str = "float32"):
        self.weights = np.asarray(weights, np.float64)
        assert self.weights.ndim == 1 and (self.weights >= 0).all()
        assert self.weights.sum() > 0
        self.accum_dtype = jnp.dtype(dtype)

    def worker_weights(self, n: int) -> np.ndarray:
        assert len(self.weights) == n, (len(self.weights), n)
        return self.weights

    def __repr__(self):
        return f"WeightedAggregator(n={len(self.weights)})"


class SignSGDAggregator(Aggregator):
    """Majority-vote 1-bit rule (Bernstein et al.) applied to the sync
    payload: each participant contributes sign(x) plus a scalar-per-entry
    magnitude |x|; the aggregate is mean|x| * sign(majority).  Lossy by
    design (changes trajectories); the point is 1-bit payload robustness."""

    def __init__(self, dtype: str = "float32"):
        self.accum_dtype = jnp.dtype(dtype)

    def encode(self, x: jax.Array) -> Dict[str, jax.Array]:
        xf = x.astype(self.accum_dtype)
        return {"sign": jnp.sign(xf), "magnitude": jnp.abs(xf)}

    def decode(self, means: Dict[str, jax.Array], like: jax.Array) -> jax.Array:
        # sign of the weighted-mean of signs == the participation-weighted
        # majority vote; exact ties collapse to 0
        return (means["magnitude"] * jnp.sign(means["sign"])).astype(like.dtype)

    def __repr__(self):
        return "SignSGDAggregator()"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
AGGREGATORS = {
    "mean": MeanAggregator,
    "compressed": CompressedAggregator,
    "bf16": CompressedAggregator,
    "weighted": WeightedAggregator,
    "sign": SignSGDAggregator,
    "signsgd": SignSGDAggregator,
}

AggregatorLike = Union[str, Aggregator, None]


def make_aggregator(spec: AggregatorLike = None, *,
                    sync_dtype: Optional[str] = None, **kwargs) -> Aggregator:
    """Resolve an aggregator from an instance, a registry name, or the legacy
    ``sync_dtype`` flag (``'bfloat16'`` -> CompressedAggregator)."""
    if isinstance(spec, Aggregator):
        if sync_dtype is not None:
            raise ValueError(
                f"sync_dtype={sync_dtype!r} only applies when constructing "
                f"by name; got the instance {spec!r} — set its dtype at "
                f"construction instead")
        assert not kwargs, "kwargs only apply when constructing by name"
        return spec
    if spec is None:
        if sync_dtype is not None and jnp.dtype(sync_dtype) != jnp.float32:
            return CompressedAggregator(sync_dtype)
        return MeanAggregator()
    name = spec.lower()
    if name not in AGGREGATORS:
        raise KeyError(f"unknown aggregator {spec!r}; "
                       f"known: {sorted(AGGREGATORS)}")
    if sync_dtype is not None:
        kwargs.setdefault("dtype", sync_dtype)
    return AGGREGATORS[name](**kwargs)


def register_aggregator(name: str, cls) -> None:
    AGGREGATORS[name.lower()] = cls


# ---------------------------------------------------------------------------
# shared weighted-mean kernels (the logic formerly copy-pasted per topology)
# ---------------------------------------------------------------------------
def flat_worker_index(axis_names, sizes) -> jax.Array:
    """This shard's flat worker index: row-major over the replica mesh axes
    (outermost first) — the same order ``worker_axis_spec`` lays the leading
    worker axis out in, so ``gathered[flat_worker_index(...)]`` is always
    this shard's own row.  Only callable inside ``shard_map``."""
    idx = jnp.zeros((), jnp.int32)
    for a, s in zip(axis_names, sizes):
        idx = idx * s + jax.lax.axis_index(a)
    return idx



def denominator_floor(acc) -> jax.Array:
    """Positive floor for weighted-mean denominators, in the accumulation
    dtype: the dtype's smallest positive normal.  A literal ``1e-9``
    underflows to 0 in half-precision accumulation (f16/bf16 tiny is
    ~6e-5/~1e-38 but 1e-9 rounds to 0 in f16), so an all-masked group would
    divide 0/0 = NaN; ``tiny`` keeps the quotient an exact 0 in every float
    dtype while never perturbing a real weight sum (any participating
    worker's weight dwarfs it)."""
    return jnp.asarray(jnp.finfo(jnp.dtype(acc)).tiny, acc)


def axis_weighted_mean(v: jax.Array, w: Optional[jax.Array], axes, acc) -> Any:
    """Mean of ``v`` over ``axes`` (keepdims), optionally weighted by ``w``
    (broadcastable); accumulation pinned to ``acc`` so a bf16 payload stays
    bf16 through the collective."""
    if w is None:
        return v.astype(acc).mean(axis=axes, keepdims=True, dtype=acc)
    num = (v.astype(acc) * w).sum(axis=axes, keepdims=True, dtype=acc)
    den = jnp.maximum(w.sum(axis=axes, keepdims=True, dtype=acc),
                      denominator_floor(acc))
    return num / den


def named_axis_weighted_mean(v: jax.Array, w: Optional[jax.Array],
                             axis_names, acc) -> jax.Array:
    """Named-axis counterpart of :func:`axis_weighted_mean` for shard_map
    bodies: the level-ℓ mean IS an all-reduce over the mesh axes of levels
    >= ℓ.  ``w`` is the local shard's scalar worker weight (or None)."""
    if not axis_names:
        return v.astype(acc)
    if w is None:
        return jax.lax.pmean(v.astype(acc), axis_names)
    w = jnp.asarray(w, acc).reshape(())
    num = jax.lax.psum(v.astype(acc) * w, axis_names)
    den = jnp.maximum(jax.lax.psum(w, axis_names), denominator_floor(acc))
    return num / den


def named_axis_sum(v: jax.Array, axis_names,
                   w: Optional[jax.Array] = None) -> jax.Array:
    """Wire-dtype-aware named-axis sum: the operand's OWN dtype rides the
    collective (an int32 payload psums as int32 — the widened-accumulator
    rule of the compressed allreduce; contrast the mean above, which always
    promotes to the accumulation dtype).  ``w`` is the local shard's 0/1
    participation weight, cast to the operand dtype so masked rows
    contribute exact zeros."""
    if not axis_names:
        return v
    if w is not None:
        v = v * jnp.asarray(w).astype(v.dtype)
    return jax.lax.psum(v, axis_names)


def named_axis_max(v: jax.Array, axis_names,
                   w: Optional[jax.Array] = None) -> jax.Array:
    """Wire-dtype-aware named-axis max of NON-NEGATIVE statistics (block
    amax scales): a masked-out shard's row is zeroed, never pulling a real
    max below zero."""
    if not axis_names:
        return v
    if w is not None:
        v = v * jnp.asarray(w).astype(v.dtype)
    return jax.lax.pmax(v, axis_names)


def segment_weighted_mean(v: jax.Array, w: jax.Array,
                          membership: jax.Array, acc) -> jax.Array:
    """Per-group weighted mean of flat worker values.

    v: (n, dim) payload; w: (n,) weights; membership: (N, n) one-hot.
    Returns (N, dim) group means."""
    num = membership @ (w[:, None] * v.astype(acc))
    den = jnp.maximum(membership @ w, denominator_floor(acc))[:, None]
    return num / den
