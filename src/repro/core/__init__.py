"""The paper's primary contribution: hierarchical SGD as a composable
JAX training feature (engine, topologies, groupings, divergences, bounds)."""
from repro.core.divergence import (all_divergences, downward_divergence_avg,
                                   downward_divergences, flatten_pytree_batch,
                                   global_divergence, partition_residual,
                                   per_worker_grads, upward_divergence)
from repro.core.grouping import (Grouping, contiguous, diversity_grouping,
                                 group_iid, group_noniid, random_grouping,
                                 sample_participation)
from repro.core.hierarchy import HierarchySpec, local_sgd, two_level
from repro.core.planner import (CommModel, PlanPoint, best_under_budget,
                                enumerate_plans, fastest_under_bound,
                                pareto_front)
from repro.core.hsgd import (HSGD, GroupedTopology, HSGDState, UniformTopology,
                             run)

__all__ = [
    "HSGD", "HSGDState", "GroupedTopology", "UniformTopology", "run",
    "HierarchySpec", "local_sgd", "two_level",
    "CommModel", "PlanPoint", "best_under_budget", "enumerate_plans",
    "fastest_under_bound", "pareto_front",
    "Grouping", "contiguous", "group_iid", "group_noniid", "random_grouping",
    "sample_participation", "diversity_grouping",
    "all_divergences", "downward_divergence_avg", "downward_divergences",
    "flatten_pytree_batch", "global_divergence", "partition_residual",
    "per_worker_grads", "upward_divergence",
]
