"""The paper's primary contribution: hierarchical SGD as a composable
JAX training feature (engine, topologies, aggregators, groupings,
divergences, bounds)."""
from repro.core.aggregators import (Aggregator, CompressedAggregator,
                                    MeanAggregator, SignSGDAggregator,
                                    WeightedAggregator, make_aggregator,
                                    register_aggregator)
from repro.core.divergence import (all_divergences, divergence_stack,
                                   downward_divergence_avg,
                                   downward_divergences, flatten_pytree_batch,
                                   global_divergence, partition_divergences,
                                   partition_divergences_tree,
                                   partition_residual, per_worker_grads,
                                   upward_divergence)
from repro.core.grouping import (Grouping, contiguous, diversity_grouping,
                                 group_iid, group_noniid, random_grouping,
                                 sample_participation)
from repro.core.hierarchy import HierarchySpec, local_sgd, two_level
from repro.core.hsgd import (HSGD, EngineConfig, HSGDState, Round,
                             compile_schedule, run)
from repro.core.executors import (Executor, MeshExecutor, SimExecutor,
                                  make_executor, register_executor)
from repro.core.planner import (CommModel, PlanPoint, best_under_budget,
                                enumerate_plans, fastest_under_bound,
                                pareto_front)
from repro.core.topology import (GroupedTopology, SyncEvent, Topology,
                                 UniformTopology, make_topology,
                                 register_topology)

__all__ = [
    "HSGD", "EngineConfig", "HSGDState", "Round", "compile_schedule", "run",
    "Executor", "SimExecutor", "MeshExecutor", "make_executor",
    "register_executor",
    "Topology", "SyncEvent", "GroupedTopology", "UniformTopology",
    "make_topology", "register_topology",
    "Aggregator", "MeanAggregator", "CompressedAggregator",
    "WeightedAggregator", "SignSGDAggregator", "make_aggregator",
    "register_aggregator",
    "HierarchySpec", "local_sgd", "two_level",
    "CommModel", "PlanPoint", "best_under_budget", "enumerate_plans",
    "fastest_under_bound", "pareto_front",
    "Grouping", "contiguous", "group_iid", "group_noniid", "random_grouping",
    "sample_participation", "diversity_grouping",
    "all_divergences", "divergence_stack", "downward_divergence_avg",
    "downward_divergences", "flatten_pytree_batch", "global_divergence",
    "partition_divergences", "partition_divergences_tree",
    "partition_residual", "per_worker_grads", "upward_divergence",
]
