"""Public kernel entry points.

Auto-select ``interpret=True`` off-TPU so the same call sites work in CPU
tests (interpret mode executes the kernel body in Python) and compile to real
Mosaic kernels on TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import comms as _comms
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rglru_scan import rglru_scan as _rglru
from repro.kernels.ssd_scan import ssd_scan as _ssd


@functools.cache
def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _interpret_default()
    # interpret mode is slow; shrink blocks so CPU tests stay fast
    if interpret:
        block_q = min(block_q, 32)
        block_k = min(block_k, 32)
    return _flash(q, k, v, causal=causal, window=window,
                  block_q=block_q, block_k=block_k, interpret=interpret)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 64,
             interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _interpret_default()
    if interpret:
        chunk = min(chunk, 16)
    return _ssd(x, dt, A, B, C, chunk=chunk, interpret=interpret)


def rglru_scan(a, b, *, block: int = 128, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _interpret_default()
    if interpret:
        block = min(block, 32)
    return _rglru(a, b, block=block, interpret=interpret)


# -- communication codecs ----------------------------------------------------
# NOTE: the block size is part of a codec's *numerics* (scales are per
# block), so these entry points shrink it in interpret mode like the other
# kernels — fast CPU tests, consistent within a platform — while the
# ``repro.comms`` compressors pin their configured block explicitly via
# ``repro.kernels.comms`` so a codec's wire format never depends on where
# it traced.
def _comm_block(block: int, interpret: bool) -> int:
    return min(block, 64) if interpret else block


def int8_quantize(x, *, block: int = 256, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _interpret_default()
    return _comms.int8_quantize(x, block=_comm_block(block, interpret),
                                interpret=interpret)


def int8_dequantize(q, scale, *, block: int = 256,
                    interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _interpret_default()
    return _comms.int8_dequantize(q, scale,
                                  block=_comm_block(block, interpret),
                                  interpret=interpret)


def int8_scale_quantize(x, scale, *, block: int = 256,
                        interpret: Optional[bool] = None):
    """Quantize against a caller-supplied per-block scale.  The ``block``
    here is pinned by the scale's shape (one scale per block), so unlike the
    other comms entry points it is NOT shrunk in interpret mode — the caller
    already committed to a blocking when it computed the scales."""
    if interpret is None:
        interpret = _interpret_default()
    return _comms.int8_scale_quantize(x, scale, block=block,
                                      interpret=interpret)


def topk_decode_reduce(vals, idx, *, size: int, block: int = 256,
                       interpret: Optional[bool] = None):
    # per output element the sum order over sparse entries is independent of
    # the block size, so the interpret-mode shrink never changes values
    if interpret is None:
        interpret = _interpret_default()
    return _comms.topk_decode_reduce(vals, idx, size=size,
                                     block=_comm_block(block, interpret),
                                     interpret=interpret)


def sign_pack(x, *, block: int = 1024, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _interpret_default()
    return _comms.sign_pack(x, block=_comm_block(block, interpret),
                            interpret=interpret)


def sign_unpack(bits, scale, *, size: int, block: int = 1024,
                interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _interpret_default()
    return _comms.sign_unpack(bits, scale, size=size,
                              block=_comm_block(block, interpret),
                              interpret=interpret)
