"""Public kernel entry points.

Auto-select ``interpret=True`` off-TPU so the same call sites work in CPU
tests (interpret mode executes the kernel body in Python) and compile to real
Mosaic kernels on TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rglru_scan import rglru_scan as _rglru
from repro.kernels.ssd_scan import ssd_scan as _ssd


@functools.cache
def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _interpret_default()
    # interpret mode is slow; shrink blocks so CPU tests stay fast
    if interpret:
        block_q = min(block_q, 32)
        block_k = min(block_k, 32)
    return _flash(q, k, v, causal=causal, window=window,
                  block_q=block_q, block_k=block_k, interpret=interpret)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 64,
             interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _interpret_default()
    if interpret:
        chunk = min(chunk, 16)
    return _ssd(x, dt, A, B, C, chunk=chunk, interpret=interpret)


def rglru_scan(a, b, *, block: int = 128, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _interpret_default()
    if interpret:
        block = min(block, 32)
    return _rglru(a, b, block=block, interpret=interpret)
