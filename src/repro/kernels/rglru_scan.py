"""RG-LRU linear recurrence (RecurrentGemma/Griffin) as a Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t over the sequence, per channel.  TPU adaptation:
the sequence is tiled into blocks; the carry h lives in VMEM scratch across
the sequential block grid dimension, and *within* a block the recurrence is
evaluated in log-space prefix form (cumprod of a via cumsum of log a) so the
inner loop is vector ops, not a Python-level scan — the VPU-friendly analogue
of the GPU's warp-parallel associative scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_EPS = 1e-30


def _rglru_kernel(loga_ref, b_ref, y_ref, h_scr, *, block: int):
    ib = pl.program_id(1)

    @pl.when(ib == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    log_a = loga_ref[0].astype(jnp.float32)   # (Q, W), <= 0
    b = b_ref[0].astype(jnp.float32)          # (Q, W)

    # prefix products A_t = prod_{j<=t} a_j  via cumsum in log space
    cuml = jnp.cumsum(log_a, axis=0)          # (Q, W)
    At = jnp.exp(cuml)
    # h_t = A_t * (h0 + sum_{j<=t} b_j / A_j); guard tiny A_j by clamping the
    # log-prefix (a_j in (0,1), so A_j decays — clamp keeps this stable for
    # the block sizes used; exactness is asserted against the jnp oracle)
    inv = jnp.exp(-jnp.maximum(cuml, jnp.log(_EPS)))
    contrib = jnp.cumsum(b * inv, axis=0)
    h0 = h_scr[...]                           # (1, W)
    hs = At * (h0 + contrib)
    y_ref[0] = hs.astype(y_ref.dtype)
    h_scr[...] = hs[-1:]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, *, block: int = 128,
               interpret: bool = False) -> jax.Array:
    """a, b: (Bt, S, W), 0 < a < 1.  Returns h: (Bt, S, W)."""
    bt, s, w = a.shape
    s_p = -(-s // block) * block
    log_a = jnp.log(jnp.maximum(a.astype(jnp.float32), _EPS))
    if s_p != s:
        log_a = jnp.pad(log_a, ((0, 0), (0, s_p - s), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, s_p - s), (0, 0)))
    nb = s_p // block
    out = pl.pallas_call(
        functools.partial(_rglru_kernel, block=block),
        grid=(bt, nb),
        in_specs=[
            pl.BlockSpec((1, block, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block, w), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, w), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bt, s_p, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, w), jnp.float32)],
        interpret=interpret,
    )(log_a, b)
    return out[:, :s]
