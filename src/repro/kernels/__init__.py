from repro.kernels.ops import (flash_attention, int8_dequantize,
                               int8_quantize, rglru_scan, sign_pack,
                               sign_unpack, ssd_scan)

__all__ = ["flash_attention", "rglru_scan", "ssd_scan",
           "int8_quantize", "int8_dequantize", "sign_pack", "sign_unpack"]
