from repro.kernels.ops import flash_attention, rglru_scan, ssd_scan

__all__ = ["flash_attention", "rglru_scan", "ssd_scan"]
