"""Blocked online-softmax attention (flash-attention style) for TPU.

TPU adaptation notes (vs the CUDA original): tiles live in VMEM and are sized
for the 128-lane MXU (block_q/block_k multiples of 128 in production; tests
sweep smaller blocks in interpret mode).  The kernel keeps running max / sum /
accumulator in VMEM scratch across the k-block grid dimension (TPU grids
iterate the minor dimension sequentially, which substitutes for the CUDA
softmax-rescaling loop).  Supports causal masking, sliding windows (for the
gemma3 / mixtral / recurrentgemma 'local' layers) and GQA via q-head ->
kv-head index mapping (no materialized repeat).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: Optional[int],
                 block_q: int, block_k: int, nk: int, sk_valid: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                      # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                      # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                      # (bk, d)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale        # (bq, bk)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    mask = kpos < sk_valid
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                                    # (bq, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                            # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                        # (bq, 1)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hk, D), Hq % Hk == 0.
    Returns (B, Sq, Hq, D). Sequences are padded to block multiples here."""
    b, sq, hq, d = q.shape
    _, sk, hk, _ = k.shape
    assert hq % hk == 0
    group = hq // hk
    scale = 1.0 / np.sqrt(d)

    sq_p = -(-sq // block_q) * block_q
    sk_p = -(-sk // block_k) * block_k
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))

    qt = q.transpose(0, 2, 1, 3)                           # (B, Hq, Sq, D)
    kt = k.transpose(0, 2, 1, 3)                           # (B, Hk, Sk, D)
    vt = v.transpose(0, 2, 1, 3)

    nq = sq_p // block_q
    nk = sk_p // block_k
    grid = (b, hq, nq, nk)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk, sk_valid=sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, h, iq, ik: (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, h, iq, ik: (bb, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, h, iq, ik: (bb, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, h, iq, ik: (bb, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)

    out = out.transpose(0, 2, 1, 3)                        # (B, Sq, Hq, D)
    return out[:, :sq]
