"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, H, D) (GQA repeat done by caller).
    Causal alignment assumes q position i == k position i (Sq == Sk)."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Sequential (non-chunked) SSD recurrence — the ground truth.

    x: (Bt, S, H, P); dt: (Bt, S, H) >= 0; A: (H,) negative; B, C: (Bt, S, N).
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t
    """
    bt, s, h, p = x.shape
    n = B.shape[-1]

    def step(carry, inp):
        xt, dtt, Bt, Ct = inp
        dA = jnp.exp(dtt * A)                                   # (Bt,H)
        carry = carry * dA[..., None, None] + \
            jnp.einsum("bh,bn,bhp->bhpn", dtt, Bt, xt)
        y = jnp.einsum("bn,bhpn->bhp", Ct, carry)
        return carry, y

    init = jnp.zeros((bt, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(B.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C.astype(jnp.float32), 1, 0))
    final, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def _blocked(x: jax.Array, block: int) -> Tuple[jax.Array, int]:
    """(R, C) -> (R, nb, block) zero-padded view, plus nb."""
    r, c = x.shape
    nb = -(-c // block)
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, nb * block - c)))
    return xp.reshape(r, nb, block), nb


def int8_ref(x: jax.Array, block: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-block max-scale int8 oracle: (q (R, C), scale (R, nb), roundtrip)."""
    r, c = x.shape
    xb, nb = _blocked(x, block)
    scale = jnp.abs(xb).max(axis=-1) / 127.0                    # (R, nb)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(xb * inv[..., None]), -127, 127)
    rt = (q * scale[..., None]).reshape(r, nb * block)[:, :c]
    return q.astype(jnp.int8).reshape(r, nb * block)[:, :c], scale, rt


def int8_scale_quant_ref(x: jax.Array, scale: jax.Array,
                         block: int) -> jax.Array:
    """Shared-scale int8 quantization oracle: q = clip(round(x / scale))
    per block, with a zero scale mapping to q = 0."""
    r, c = x.shape
    xb, nb = _blocked(x, block)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(xb * inv[..., None]), -127, 127)
    return q.astype(jnp.int8).reshape(r, nb * block)[:, :c]


def topk_reduce_ref(vals: jax.Array, idx: jax.Array, size: int) -> jax.Array:
    """Scatter-add oracle for the fused top-k decode-reduce: (M, K) sparse
    payloads summed into one dense (size,) f32 buffer."""
    return jnp.zeros((size,), jnp.float32).at[idx.ravel()].add(
        vals.ravel().astype(jnp.float32))


def sign_ref(x: jax.Array, block: int) -> Tuple[jax.Array, jax.Array]:
    """1-bit sign oracle: (scale (R, nb) = mean|x| over real entries,
    roundtrip (R, C) = +-scale by sign(x), zeros counted as +)."""
    r, c = x.shape
    xb, nb = _blocked(x, block)
    counts = np.full((nb,), block, np.float32)
    counts[-1] = c - (nb - 1) * block
    scale = jnp.abs(xb).sum(axis=-1) / counts                   # (R, nb)
    rt = jnp.where(xb >= 0, scale[..., None], -scale[..., None])
    return scale, rt.reshape(r, nb * block)[:, :c]


def rglru_ref(a: jax.Array, b: jax.Array,
              h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Sequential linear recurrence h_t = a_t h_{t-1} + b_t.
    a, b: (B, S, W). Returns (h_1..h_S stacked, h_S)."""
    def step(carry, inp):
        at, bt = inp
        carry = at * carry + bt
        return carry, carry

    bt = a.shape[0]
    w = a.shape[-1]
    init = jnp.zeros((bt, w), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    final, hs = jax.lax.scan(
        step, init, (jnp.moveaxis(a.astype(jnp.float32), 1, 0),
                     jnp.moveaxis(b.astype(jnp.float32), 1, 0)))
    return jnp.moveaxis(hs, 0, 1), final
