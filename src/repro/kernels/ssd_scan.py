"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

TPU adaptation: the chunk-local "dual" quadratic form runs on the MXU
(chunk x chunk matmuls); the inter-chunk state (H, P, N) is carried in VMEM
scratch across the sequential chunk grid dimension — the TPU analogue of the
paper's warp-level chunk recurrence on GPU.  One grid cell = (batch, chunk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)       # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)     # (Q, H)
    A = a_ref[...].astype(jnp.float32)     # (H,)
    B = b_ref[0].astype(jnp.float32)       # (Q, N)
    C = c_ref[0].astype(jnp.float32)       # (Q, N)

    dA = dt * A                            # (Q, H), negative
    cum = jnp.cumsum(dA, axis=0)           # (Q, H)

    # intra-chunk dual form
    lt = cum[:, None, :] - cum[None, :, :]                 # (Qi, Qj, H)
    causal = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(causal[..., None], lt, -jnp.inf))
    g = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Qi, Qj)
    m = g[..., None] * decay * dt[None, :, :]              # (Qi, Qj, H)
    y_intra = jnp.einsum("ijh,jhp->ihp", m, x)

    # inter-chunk contribution from the carried state
    state = state_scr[...]                                 # (H, P, N)
    y_inter = jnp.einsum("in,ih,hpn->ihp", C, jnp.exp(cum), state)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update for the next chunk
    rev = jnp.exp(cum[-1][None, :] - cum)                  # (Q, H)
    upd = jnp.einsum("jh,jn,jhp->hpn", dt * rev, B, x)
    state_scr[...] = state * jnp.exp(cum[-1])[:, None, None] + upd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int = 64,
             interpret: bool = False) -> jax.Array:
    """x: (Bt, S, H, P); dt: (Bt, S, H); A: (H,); B, C: (Bt, S, N).
    Returns y: (Bt, S, H, P).  S is padded to a chunk multiple here."""
    bt, s, h, p = x.shape
    n = B.shape[-1]
    s_p = -(-s // chunk) * chunk
    if s_p != s:
        x = jnp.pad(x, ((0, 0), (0, s_p - s), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, s_p - s), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, s_p - s), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, s_p - s), (0, 0)))
    nc = s_p // chunk
    grid = (bt, nc)

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, h), lambda b, c: (b, c, 0)),
            pl.BlockSpec((h,), lambda b, c: (0,)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, h, p), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bt, s_p, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((h, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return out[:, :s]
