"""Communication codecs as Pallas TPU kernels.

The payload a ``SyncEvent`` moves is a first-class design axis (signSGD,
QSGD, DGC); these kernels produce the *wire formats* the ``repro.comms``
codecs ship over the collective:

* :func:`int8_quantize` / :func:`int8_dequantize` — per-block symmetric int8
  (block max-scale): ``q = round(x * 127 / max|x_block|)``, one f32 scale per
  block.  ~4x fewer payload bytes than f32.
* :func:`sign_pack` / :func:`sign_unpack` — 1-bit sign compression: 8 signs
  packed per uint8 plus a per-block magnitude ``mean|x_block|`` (the L2-optimal
  scale for a sign vector, as in 1-bit SGD / EF-signSGD).  ~32x fewer bytes.
* :func:`int8_scale_quantize` — quantize against a caller-supplied (shared
  group-max) scale, the encode side of the int8 compressed allreduce: every
  group member's int8 payload is summable in an int32 accumulator.
* :func:`topk_decode_reduce` — fused decode-reduce of a ragged-gathered
  top-k (values, indices) payload into one dense sum, the receive side of
  the top-k compressed collective.

All kernels view a payload as rows of ``block`` contiguous elements (rows =
workers or worker-shards, columns = the flat bucket).  The wrappers zero-pad
the trailing block and pass the count of *real* elements per block, so block
scales are computed over real entries only — this keeps the codecs idempotent
(re-encoding a decoded payload is a fixed point), which the property suite
asserts.  Like the other repo kernels they run compiled on TPU and under
``interpret=True`` elsewhere (selected by the :mod:`repro.kernels.ops`
entry points).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pad_cols(x: jax.Array, block: int) -> Tuple[jax.Array, int]:
    c = x.shape[-1]
    nb = -(-c // block)
    cp = nb * block
    if cp != c:
        x = jnp.pad(x, ((0, 0), (0, cp - c)))
    return x, nb


def _block_counts(c: int, block: int, nb: int) -> jax.Array:
    """(1, nb) f32: number of real (unpadded) elements in each block."""
    full = jnp.full((1, nb), float(block), jnp.float32)
    tail = c - (nb - 1) * block
    return full.at[0, nb - 1].set(float(tail))


# ---------------------------------------------------------------------------
# int8: per-block symmetric quantization, block max-scale
# ---------------------------------------------------------------------------
def _int8_quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                      # (1, B)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)      # (1, 1)
    scale = amax / 127.0
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q_ref[...] = jnp.clip(jnp.round(x * inv), -127.0, 127.0).astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def int8_quantize(x: jax.Array, *, block: int = 256,
                  interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (R, C) float -> (q int8 (R, C), scale f32 (R, ceil(C/block))).

    Zero padding never disturbs the block max, so the trailing block needs no
    special casing here (unlike :func:`sign_pack`)."""
    r, c = x.shape
    xp, nb = _pad_cols(x.astype(jnp.float32), block)
    q, s = pl.pallas_call(
        _int8_quant_kernel,
        grid=(r, nb),
        in_specs=[pl.BlockSpec((1, block), lambda i, j: (i, j))],
        out_specs=(pl.BlockSpec((1, block), lambda i, j: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j: (i, j))),
        out_shape=(jax.ShapeDtypeStruct((r, nb * block), jnp.int8),
                   jax.ShapeDtypeStruct((r, nb), jnp.float32)),
        interpret=interpret,
    )(xp)
    return q[:, :c], s


def _int8_dequant_kernel(q_ref, s_ref, y_ref):
    y_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def int8_dequantize(q: jax.Array, scale: jax.Array, *, block: int = 256,
                    interpret: bool = False) -> jax.Array:
    """(q int8 (R, C), scale f32 (R, nb)) -> x f32 (R, C)."""
    r, c = q.shape
    qp, nb = _pad_cols(q, block)
    assert scale.shape == (r, nb), (scale.shape, (r, nb))
    y = pl.pallas_call(
        _int8_dequant_kernel,
        grid=(r, nb),
        in_specs=[pl.BlockSpec((1, block), lambda i, j: (i, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, nb * block), jnp.float32),
        interpret=interpret,
    )(qp, scale)
    return y[:, :c]


def _int8_scale_quant_kernel(x_ref, s_ref, q_ref):
    x = x_ref[...].astype(jnp.float32)                      # (1, B)
    scale = s_ref[...]                                      # (1, 1)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q_ref[...] = jnp.clip(jnp.round(x * inv), -127.0, 127.0).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def int8_scale_quantize(x: jax.Array, scale: jax.Array, *, block: int = 256,
                        interpret: bool = False) -> jax.Array:
    """(x (R, C) float, scale f32 (R, ceil(C/block))) -> q int8 (R, C).

    Quantize against a CALLER-supplied per-block scale instead of the local
    block max — the compressed-allreduce form, where every group member
    quantizes against the same group-max scale so the int8 payloads are
    summable in an int32 accumulator (|sum q| <= 127 * members, exact)."""
    r, c = x.shape
    xp, nb = _pad_cols(x.astype(jnp.float32), block)
    assert scale.shape == (r, nb), (scale.shape, (r, nb))
    q = pl.pallas_call(
        _int8_scale_quant_kernel,
        grid=(r, nb),
        in_specs=[pl.BlockSpec((1, block), lambda i, j: (i, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, nb * block), jnp.int8),
        interpret=interpret,
    )(xp, scale)
    return q[:, :c]


# ---------------------------------------------------------------------------
# top-k: fused decode-reduce of a ragged-gathered (values, indices) payload
# ---------------------------------------------------------------------------
def _topk_decode_reduce_kernel(v_ref, i_ref, o_ref, *, block: int):
    j = pl.program_id(0)
    v = v_ref[...].astype(jnp.float32).reshape(-1, 1)       # (m*k, 1)
    idx = i_ref[...].reshape(-1, 1) - j * block             # (m*k, 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    hit = (idx == cols).astype(jnp.float32)                 # (m*k, block)
    o_ref[...] = jnp.sum(v * hit, axis=0, keepdims=True)    # (1, block)


@functools.partial(jax.jit, static_argnames=("size", "block", "interpret"))
def topk_decode_reduce(vals: jax.Array, idx: jax.Array, *, size: int,
                       block: int = 256, interpret: bool = False) -> jax.Array:
    """(vals f32 (M, K), idx int32 (M, K)) -> dense sum f32 (size,).

    The top-k compressed collective's receive side: M gathered sparse
    payloads (group members x k entries each) scatter-summed into one dense
    buffer in a single fused kernel — decode and reduce never materialize M
    dense payloads.  Each grid step owns one ``block``-wide output slice and
    masks the (M*K) entries that land in it; per output element the sum
    order over entries is fixed regardless of ``block``."""
    m, k = vals.shape
    assert idx.shape == (m, k), (idx.shape, (m, k))
    nb = -(-size // block)
    out = pl.pallas_call(
        functools.partial(_topk_decode_reduce_kernel, block=block),
        grid=(nb,),
        in_specs=[pl.BlockSpec((m, k), lambda j: (0, 0)),
                  pl.BlockSpec((m, k), lambda j: (0, 0))],
        out_specs=pl.BlockSpec((1, block), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, nb * block), jnp.float32),
        interpret=interpret,
    )(vals, idx.astype(jnp.int32))
    return out[0, :size]


# ---------------------------------------------------------------------------
# sign: 1-bit pack into uint8, block mean-|x| magnitude
# ---------------------------------------------------------------------------
def _sign_pack_kernel(x_ref, d_ref, b_ref, s_ref, *, block: int):
    x = x_ref[...].astype(jnp.float32)                      # (1, B)
    s_ref[...] = (jnp.sum(jnp.abs(x)) / d_ref[0, 0]).reshape(1, 1)
    bits = (x >= 0).reshape(block // 8, 8).astype(jnp.int32)
    shift = jax.lax.broadcasted_iota(jnp.int32, (block // 8, 8), 1)
    packed = jnp.sum(bits << shift, axis=1)
    b_ref[...] = packed.astype(jnp.uint8).reshape(1, block // 8)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sign_pack(x: jax.Array, *, block: int = 1024,
              interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (R, C) float -> (bits uint8 (R, ceil(C/block)*block/8),
    scale f32 (R, ceil(C/block))).

    Bit k of byte j in a block is ``x[8j+k] >= 0``; the block scale is
    ``mean|x|`` over the block's *real* entries (the padded tail is excluded
    via the per-block denominator), so a re-encoded payload keeps its scale."""
    assert block % 8 == 0, block
    r, c = x.shape
    xp, nb = _pad_cols(x.astype(jnp.float32), block)
    counts = _block_counts(c, block, nb)
    bits, s = pl.pallas_call(
        functools.partial(_sign_pack_kernel, block=block),
        grid=(r, nb),
        in_specs=[pl.BlockSpec((1, block), lambda i, j: (i, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, j))],
        out_specs=(pl.BlockSpec((1, block // 8), lambda i, j: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j: (i, j))),
        out_shape=(jax.ShapeDtypeStruct((r, nb * block // 8), jnp.uint8),
                   jax.ShapeDtypeStruct((r, nb), jnp.float32)),
        interpret=interpret,
    )(xp, counts)
    return bits, s


def _sign_unpack_kernel(b_ref, s_ref, y_ref, *, block: int):
    packed = b_ref[...].astype(jnp.int32).reshape(block // 8, 1)
    shift = jax.lax.broadcasted_iota(jnp.int32, (block // 8, 8), 1)
    bits = (packed >> shift) & 1
    sgn = bits.astype(jnp.float32) * 2.0 - 1.0
    y_ref[...] = (sgn * s_ref[0, 0]).reshape(1, block)


@functools.partial(jax.jit, static_argnames=("size", "block", "interpret"))
def sign_unpack(bits: jax.Array, scale: jax.Array, *, size: int,
                block: int = 1024, interpret: bool = False) -> jax.Array:
    """(bits uint8 (R, nb*block/8), scale f32 (R, nb)) -> x f32 (R, size):
    ``+scale`` where the bit is set, ``-scale`` where clear."""
    assert block % 8 == 0, block
    r = bits.shape[0]
    nb = -(-size // block)
    assert bits.shape == (r, nb * block // 8), (bits.shape, (r, nb * block // 8))
    assert scale.shape == (r, nb), (scale.shape, (r, nb))
    y = pl.pallas_call(
        functools.partial(_sign_unpack_kernel, block=block),
        grid=(r, nb),
        in_specs=[pl.BlockSpec((1, block // 8), lambda i, j: (i, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, nb * block), jnp.float32),
        interpret=interpret,
    )(bits, scale)
    return y[:, :size]
