"""Wire accounting: what a sync schedule actually moves, in bytes.

The paper's argument is convergence per *communication cost*, and Multi-Level
Local SGD's model prices each hierarchy level separately — yet nothing in the
repo measured either.  :class:`WireStats` closes that gap **statically**: it
is computed from the encoded payload *specs* (shapes + dtypes of the codec's
wire arrays), never from device values, so the accounting costs nothing at
run time and is exact by construction.

Cost model (documented, deliberately simple): a level-ℓ sync aggregates
within each level-(ℓ-1) subtree, so one encoded payload crosses every tree
edge at tiers ℓ..M on the way up — ``sum_{j=ℓ}^{M} n_j`` payloads, with
``n_j = prod(group_sizes[:j])`` the number of level-j subtrees.  We count the
uplink only (the downlink mirrors it; ratios between codecs are unchanged).
For a :class:`~repro.core.topology.GroupedTopology`, a global sync moves
``n + N`` payloads and a (possibly partial) group sync one payload per
participating worker.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.topology import GroupedTopology, SyncEvent, Topology


@dataclasses.dataclass(frozen=True)
class WireArray:
    """One array of a codec's wire format (per worker, per sync)."""
    name: str
    shape: Tuple[int, ...]
    dtype: str

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * \
            jnp.dtype(self.dtype).itemsize


class WireStats:
    """Per-level byte accounting for one (topology, payload spec) pair.

    payload: the codec wire arrays ONE worker ships at ONE sync event (the
    model payload after bucketization + compression).  ``f32_bytes`` is the
    uncompressed f32 baseline for the same element count, so
    ``compression_ratio`` is the codec's payload reduction.
    """

    def __init__(self, topology: Topology, payload: Tuple[WireArray, ...],
                 n_elements: int):
        self.topology = topology
        self.payload = tuple(payload)
        self.n_elements = int(n_elements)

    # -- payload ------------------------------------------------------------
    @property
    def payload_bytes(self) -> int:
        return sum(a.nbytes for a in self.payload)

    @property
    def f32_bytes(self) -> int:
        return 4 * self.n_elements

    @property
    def wire_dtypes(self) -> Tuple[str, ...]:
        """Sorted distinct dtypes of the declared wire payload — what the
        codec CLAIMS goes on the wire.  The analysis layer's R2 rule compares
        this against the dtypes the lowered collectives actually move."""
        return tuple(sorted({jnp.dtype(a.dtype).name for a in self.payload}))

    @property
    def compression_ratio(self) -> float:
        return self.f32_bytes / max(self.payload_bytes, 1)

    # -- per-event ----------------------------------------------------------
    def payload_count(self, event: SyncEvent) -> int:
        """Encoded payloads crossing the wire (uplink) for one event."""
        topo = self.topology
        spec = getattr(topo, "spec", None)
        if spec is not None:
            return sum(spec.n_at_level(j)
                       for j in range(event.level, spec.num_levels + 1))
        if isinstance(topo, GroupedTopology):
            sizes = np.asarray(topo.grouping.sizes)
            if event.level == 1:
                return int(sizes.sum()) + topo.grouping.N
            if event.groups is None:
                return int(sizes.sum())
            return int(sizes[np.asarray(event.groups)].sum())
        return topo.n  # fallback: one payload per worker

    def bytes_for_event(self, event: Optional[SyncEvent]) -> int:
        if event is None:
            return 0
        return self.payload_count(event) * self.payload_bytes

    # -- per-schedule ---------------------------------------------------------
    def step_bytes(self, T: int, t0: int = 0) -> List[int]:
        """Bytes moved by the sync (if any) after each of steps t0..t0+T-1."""
        return [self.bytes_for_event(self.topology.event_at(t))
                for t in range(t0, t0 + T)]

    def per_level(self) -> Dict[str, Dict[str, int]]:
        """Per-level traffic derived from the ACTUAL events of one global
        period — partial-group events are costed as fired (mean over the
        level's events), so the summary always agrees with the per-step
        history (a heterogeneous GroupedTopology never fires the
        full-group level-2 sync its periods tuple might suggest)."""
        G = self.topology.periods[0]
        events: Dict[int, List[SyncEvent]] = {}
        for t in range(G):
            ev = self.topology.event_at(t)
            if ev is not None:
                events.setdefault(ev.level, []).append(ev)

        def mean(vals):
            m = sum(vals) / len(vals)
            return int(m) if float(m).is_integer() else m

        return {f"L{l}": {
            "payloads_per_sync": mean([self.payload_count(e) for e in evs]),
            "bytes_per_sync": mean([self.bytes_for_event(e) for e in evs]),
            "syncs_per_period": len(evs),
            "period": self.topology.periods[l - 1],
        } for l, evs in sorted(events.items())}

    def summary(self, T: Optional[int] = None) -> Dict:
        """JSON-able report; with ``T``, adds schedule totals over T steps."""
        out = {
            "payload": [dataclasses.asdict(a) for a in self.payload],
            "payload_bytes_per_worker": self.payload_bytes,
            "f32_bytes_per_worker": self.f32_bytes,
            "compression_ratio": round(self.compression_ratio, 3),
            "per_level": self.per_level(),
        }
        if T:
            sb = self.step_bytes(T)
            out["steps"] = T
            out["total_bytes"] = int(sum(sb))
            out["bytes_per_step"] = sum(sb) / T
        return out
