"""repro.comms — what a sync event MOVES, made explicit and measurable.

Three parts (see the module docstrings for the design notes):

* :mod:`repro.comms.flat` — ``FlatBucket``: fuse a worker-stacked pytree
  into one contiguous buffer per dtype, so a sync aggregates O(dtypes)
  buffers instead of O(leaves) arrays;
* :mod:`repro.comms.codecs` — the ``Compressor`` registry (identity / int8 /
  sign-1bit / top-k with error feedback), Pallas-backed wire codecs that
  compose with any ``Aggregator``;
* :mod:`repro.comms.wire` — ``WireStats``: static per-level bytes-per-sync
  accounting from the encoded payload specs.

Enable on an engine with ``HSGD(..., comms="int8")`` (or a
:class:`~repro.comms.sync.Comms` for full control); the default ``comms=None``
is bitwise-identical to the pre-comms engine.
"""
from repro.comms.codecs import (COMPRESSORS, Compressor, IdentityCompressor,
                                Int8Compressor, SignCompressor,
                                TopKCompressor, make_compressor,
                                register_compressor)
from repro.comms.flat import FlatBucket
from repro.comms.sync import Comms, CommsLike, make_comms
from repro.comms.wire import WireArray, WireStats

__all__ = [
    "Comms", "CommsLike", "make_comms",
    "FlatBucket",
    "Compressor", "IdentityCompressor", "Int8Compressor", "SignCompressor",
    "TopKCompressor", "COMPRESSORS", "make_compressor", "register_compressor",
    "WireArray", "WireStats",
]
