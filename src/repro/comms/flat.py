"""FlatBucket: DDP-style bucketization of a worker-stacked pytree.

The engine's sync events historically aggregated pytrees leaf by leaf — one
collective (or one reshape-mean) per parameter array, O(leaves) sync
operands in the lowered program.  A :class:`FlatBucket` flattens the tree
into ONE contiguous ``(workers, length)`` buffer per dtype (dtypes cannot
share a buffer without changing the payload bytes), so a sync event
aggregates O(dtypes) fused buffers instead; the inverse spec — which slice
of which bucket is which leaf — is computed once per tree signature and
cached, so steady-state rounds pay only the concatenate/slice data movement
that XLA fuses anyway.

Leaves keep their leading worker axis: under the sim executor buffers are
``(n, length)``, under the mesh executor each shard flattens its own
``(1, ...)`` leaves to ``(1, length)`` and the named-axis collective runs on
the fused buffer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one leaf lives inside its bucket."""
    bucket: str                 # dtype-name key
    offset: int                 # element offset within the per-worker row
    size: int                   # elements per worker
    shape: Tuple[int, ...]      # full leaf shape (worker axis included)
    dtype: Any


@dataclasses.dataclass(frozen=True)
class FlatBucket:
    """Cached flatten/unflatten plan for one tree signature.

    Build via :meth:`plan` (memoized on ``(treedef, shapes, dtypes)``);
    ``flatten``/``unflatten`` are exact inverses for any tree matching the
    signature — bucketization alone never changes values, only layout.
    """
    treedef: Any
    slots: Tuple[LeafSlot, ...]
    lengths: Dict[str, int]     # per-worker elements per bucket
    dtypes: Dict[str, Any]      # bucket key -> jnp dtype

    @classmethod
    def plan(cls, tree) -> "FlatBucket":
        leaves, treedef = jax.tree.flatten(tree)
        sig = (treedef, tuple((np.shape(l), jnp.dtype(l.dtype).name)
                              for l in leaves))
        hit = _PLANS.get(sig)
        if hit is not None:
            return hit
        slots, lengths, dtypes = [], {}, {}
        for leaf in leaves:
            shape = np.shape(leaf)
            assert len(shape) >= 1, \
                "bucketized leaves need a leading worker axis"
            key = jnp.dtype(leaf.dtype).name
            size = int(np.prod(shape[1:], dtype=np.int64)) if len(shape) > 1 \
                else 1
            off = lengths.get(key, 0)
            slots.append(LeafSlot(key, off, size, tuple(shape), leaf.dtype))
            lengths[key] = off + size
            dtypes[key] = leaf.dtype
        fb = cls(treedef, tuple(slots), dict(lengths), dict(dtypes))
        _PLANS[sig] = fb
        return fb

    def flatten(self, tree) -> Dict[str, jax.Array]:
        """tree -> {dtype-name: (workers, length)} fused buffers."""
        leaves = self.treedef.flatten_up_to(tree)
        rows: Dict[str, list] = {}
        for slot, leaf in zip(self.slots, leaves):
            rows.setdefault(slot.bucket, []).append(
                leaf.reshape(leaf.shape[0], -1))
        return {k: (v[0] if len(v) == 1 else jnp.concatenate(v, axis=1))
                for k, v in rows.items()}

    def unflatten(self, bufs: Dict[str, jax.Array]):
        """Inverse of :meth:`flatten` (tolerates a changed worker-axis size,
        e.g. per-shard buffers under the mesh executor)."""
        leaves = []
        for slot in self.slots:
            buf = bufs[slot.bucket]
            piece = jax.lax.slice_in_dim(buf, slot.offset,
                                         slot.offset + slot.size, axis=1)
            leaves.append(piece.reshape((buf.shape[0],) + slot.shape[1:])
                          .astype(slot.dtype))
        return self.treedef.unflatten(leaves)


_PLANS: Dict[Any, FlatBucket] = {}
