"""WireOps: the reduction surface a codec's compressed collective targets.

The legacy sync path round-trips every worker's payload through
encode→decode and hands the *decoded f32* tree to the executor's reduce —
so the declared compression never reaches the collective.  A ``WireOps``
instead exposes the executor's reduction vocabulary directly to the codec
(:meth:`~repro.comms.codecs.Compressor.reduce`), so the operand on the wire
is the ENCODED payload:

* :meth:`mean` — the aggregator's f32 group mean (the identity codec's
  whole lowering; bitwise-identical to ``UniformTopology.aggregate`` with
  the default :class:`~repro.core.aggregators.MeanAggregator`);
* :meth:`sum` — dtype-preserving group sum: an int8 payload widened to
  int32 psums AS int32 (exact, order-independent — the int8 compressed
  allreduce);
* :meth:`max` — group max of non-negative block statistics (the shared
  quantization scale);
* :meth:`count` — participants per group (a static Python number when no
  runtime mask is threaded, so unmasked syncs fold it at trace time);
* :meth:`gathered` — ragged/packed forms that have no elementwise reduce
  (sign majority vote): hand ``fn`` the group-stacked wire arrays with the
  member axis at -2, plus the member participation mask (or None);
* :meth:`sparse_mean` — top-k (values, indices) payloads: a fused
  decode-reduce into the dense mean.

Three implementations keep the exactness ladder intact: ``SimWireOps``
(in-array reshape reduces over the worker axis — the reference arithmetic),
``MeshWireOps`` (named-axis collectives inside ``shard_map`` — psum/pmax on
the wire dtype, ``all_gather`` for ragged forms), and ``ExactWireOps``
(gather the full worker block, replay ``SimWireOps``, select this shard's
row — bitwise vs sim by construction).

Masks are 0/1 participation weights; a masked-out worker contributes
nothing to any reduction.  All group results come back broadcast over the
worker rows of the input (every member row holds its group's value), which
is the same contract ``Topology.aggregate`` keeps.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


class SimWireOps:
    """In-array reductions over the leading worker axis (the sim executor's
    form).  ``group_sizes`` + ``level`` define the member axis exactly as
    ``UniformTopology.aggregate`` does: a level-ℓ sync reduces over the
    trailing ``prod(group_sizes[ℓ-1:])`` workers of each outer group."""

    backend = "sim"

    def __init__(self, group_sizes: Sequence[int], level: int, mask=None):
        self.gs = tuple(int(g) for g in group_sizes)
        self.level = int(level)
        self.mask = mask
        self.members = _prod(self.gs[self.level - 1:])
        self.outer = _prod(self.gs) // self.members

    # -- shared shaping -----------------------------------------------------
    def _axes(self) -> Tuple[int, ...]:
        return tuple(range(self.level - 1, len(self.gs)))

    def _shaped(self, x):
        return x.reshape(self.gs + x.shape[1:])

    def _wr(self, shaped, dtype):
        if self.mask is None:
            return None
        w = jnp.asarray(self.mask).astype(dtype)
        return w.reshape(self.gs + (1,) * (shaped.ndim - len(self.gs)))

    def _restore(self, out, shaped_shape, flat_shape):
        return jnp.broadcast_to(out, shaped_shape).reshape(flat_shape)

    # -- the reduction vocabulary -------------------------------------------
    def mean(self, x):
        """Bitwise replica of ``UniformTopology.aggregate`` for the default
        MeanAggregator(f32): encode=astype(f32), axis_weighted_mean,
        decode=astype back, broadcast over the group rows."""
        from repro.core.aggregators import axis_weighted_mean
        shaped = self._shaped(x)
        wr = self._wr(shaped, jnp.float32)
        out = axis_weighted_mean(shaped.astype(jnp.float32), wr,
                                 self._axes(), jnp.float32)
        out = out.astype(x.dtype)
        return self._restore(out, shaped.shape, x.shape)

    def sum(self, x):
        """Dtype-preserving masked group sum — int32 payloads accumulate in
        int32 (exact, reassociation-free), which is the widened-accumulator
        rule of the int8 compressed allreduce."""
        shaped = self._shaped(x)
        shape = shaped.shape
        wr = self._wr(shaped, x.dtype)
        if wr is not None:
            shaped = shaped * wr
        out = shaped.sum(axis=self._axes(), keepdims=True, dtype=x.dtype)
        return self._restore(out, shape, x.shape)

    def max(self, x):
        """Masked group max of NON-NEGATIVE statistics (block amax); masked
        rows are zeroed, never lowering a real max below 0."""
        shaped = self._shaped(x)
        shape = shaped.shape
        wr = self._wr(shaped, x.dtype)
        if wr is not None:
            shaped = shaped * wr
        out = shaped.max(axis=self._axes(), keepdims=True)
        return self._restore(out, shape, x.shape)

    def count(self):
        """Participants per group: a static Python float when unmasked (no
        device work), else a per-row (n, 1) f32 array floored away from 0."""
        if self.mask is None:
            return float(self.members)
        from repro.core.aggregators import denominator_floor
        m = jnp.asarray(self.mask).astype(jnp.float32).reshape(self.gs)
        c = m.sum(axis=self._axes(), keepdims=True, dtype=jnp.float32)
        c = jnp.broadcast_to(c, self.gs).reshape(-1, 1)
        return jnp.maximum(c, denominator_floor(jnp.float32))

    def gathered(self, fn: Callable, *arrays):
        """Group-stack the (n, ...) wire arrays to (outer, members, ...),
        call ``fn(*stacked, member_mask)`` (member axis at -2; mask is
        (outer, members) or None), broadcast its (outer, ...) result back
        over the member rows."""
        g = [a.reshape((self.outer, self.members) + a.shape[1:])
             for a in arrays]
        wmask = None
        if self.mask is not None:
            wmask = jnp.asarray(self.mask).astype(jnp.float32).reshape(
                self.outer, self.members)
        out = fn(*g, wmask)
        out = jnp.broadcast_to(out[:, None],
                               (self.outer, self.members) + out.shape[1:])
        return out.reshape((self.outer * self.members,) + out.shape[2:])

    def sparse_mean(self, vals, idx, dense):
        """Sim reference for top-k: the decoded dense payload already exists
        locally, so the fused kernel is pointless — the group mean of the
        dense form IS the legacy arithmetic, bitwise."""
        del vals, idx
        return self.mean(dense)


class MeshWireOps:
    """Named-axis collectives inside ``shard_map`` (the production mesh
    lowering): psum/pmax carry the wire dtype, ragged forms all_gather the
    encoded arrays.  ``axis_names`` are the event's syncing mesh axes
    (``topology.level_axes``); ``members`` their static group size; ``mask``
    the replicated (n,) participation mask and ``widx`` this shard's flat
    worker index."""

    backend = "mesh"

    def __init__(self, axis_names: Sequence[str], members: int, mask=None,
                 widx=None):
        self.axes = tuple(axis_names)
        self.members = int(members)
        self.mask = mask
        self.widx = widx

    def _own_w(self, dtype):
        if self.mask is None:
            return None
        return jnp.asarray(self.mask).astype(dtype)[self.widx]

    def mean(self, x):
        """The aggregator's axis-collective mean (same arithmetic the
        legacy identity sync lowered to: one pmean per buffer)."""
        from repro.core.aggregators import named_axis_weighted_mean
        out = named_axis_weighted_mean(x.astype(jnp.float32),
                                       self._own_w(jnp.float32),
                                       self.axes, jnp.float32)
        return out.astype(x.dtype)

    def sum(self, x):
        from repro.core.aggregators import named_axis_sum
        return named_axis_sum(x, self.axes, self._own_w(x.dtype))

    def max(self, x):
        from repro.core.aggregators import named_axis_max
        return named_axis_max(x, self.axes, self._own_w(x.dtype))

    def count(self):
        if self.mask is None:
            return float(self.members)
        from repro.core.aggregators import denominator_floor
        c = jax.lax.psum(self._own_w(jnp.float32), self.axes)
        return jnp.maximum(c, denominator_floor(jnp.float32))

    def _member_mask(self):
        if self.mask is None:
            return None
        return jax.lax.all_gather(self._own_w(jnp.float32), self.axes)

    def gathered(self, fn: Callable, *arrays):
        """all_gather each (1, ...) wire array over the syncing axes to
        (members, ...) — the member axis lands at -2 because the per-shard
        leading worker axis of size 1 is what gets tiled."""
        g = [jax.lax.all_gather(a, self.axes, axis=0, tiled=True)
             for a in arrays]
        out = fn(*g, self._member_mask())
        return out[None]

    def sparse_mean(self, vals, idx, dense):
        """The top-k compressed collective: ragged all-gather of the
        (values, indices) payload + one Pallas fused decode-reduce into the
        dense sum, then the participant mean."""
        from repro.kernels import ops as _ops
        vg = jax.lax.all_gather(vals, self.axes, axis=0, tiled=True)
        ig = jax.lax.all_gather(idx, self.axes, axis=0, tiled=True)
        wm = self._member_mask()
        if wm is not None:
            vg = vg * wm[:, None]
        size = int(np.prod(dense.shape[1:], dtype=np.int64))
        acc = _ops.topk_decode_reduce(vg.reshape(-1, vg.shape[-1]),
                                      ig.reshape(-1, ig.shape[-1]),
                                      size=size)
        out = (acc / self.count()).reshape((1,) + dense.shape[1:])
        return out.astype(dense.dtype)


class ExactWireOps:
    """The mesh executor's ``exact=True`` form: all_gather the FULL worker
    block over every replica axis, replay :class:`SimWireOps` on it, and
    select this shard's own row — bitwise-identical to the sim trajectory
    for every codec, at n_workers x the sync bytes (verification mode)."""

    backend = "sim"  # replays the sim arithmetic

    def __init__(self, rep_axes: Sequence[str], widx,
                 group_sizes: Sequence[int], level: int, mask=None):
        self.rep = tuple(rep_axes)
        self.widx = widx
        self.sim = SimWireOps(group_sizes, level, mask)

    def _gather(self, x):
        return jax.lax.all_gather(x, self.rep, axis=0, tiled=True)

    def _pick(self, out):
        return jax.lax.dynamic_index_in_dim(out, self.widx, axis=0,
                                            keepdims=True)

    def mean(self, x):
        return self._pick(self.sim.mean(self._gather(x)))

    def sum(self, x):
        return self._pick(self.sim.sum(self._gather(x)))

    def max(self, x):
        return self._pick(self.sim.max(self._gather(x)))

    def count(self):
        c = self.sim.count()
        return c if isinstance(c, float) else self._pick(c)

    def gathered(self, fn: Callable, *arrays):
        g = [self._gather(a) for a in arrays]
        return self._pick(self.sim.gathered(fn, *g))

    def sparse_mean(self, vals, idx, dense):
        return self._pick(self.sim.sparse_mean(
            self._gather(vals), self._gather(idx), self._gather(dense)))


WireOps = (SimWireOps, MeshWireOps, ExactWireOps)
