"""The resolved comms plan: bucketization switch + codec, bound per engine.

``HSGD(..., comms=...)`` resolves its argument through :func:`make_comms`
into a :class:`Comms` (or None = comms off, the bitwise-identical default
path).  A ``Comms`` owns HOW a sync payload crosses the wire — fused
flat-buffer buckets or raw leaves, and through which codec — while staying
agnostic to WHO reduces it: executors pass their own ``reduce_fn`` (the
topology's segment-mean under sim, the aggregator's named-axis collective
under mesh), so one comms plan serves both backends and the aggregator's
``encode``/mean/``decode`` contract is untouched.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.comms.codecs import Compressor, CompressorLike, make_compressor
from repro.comms.flat import FlatBucket
from repro.comms.wire import WireArray


class Comms:
    """compressor: a codec instance, registry name, or None (identity).
    bucket: fuse the tree into one buffer per dtype before encoding
    (O(dtypes) sync operands); False keeps leaf-wise payloads (O(leaves),
    but still codec-compressed).  Extra kwargs construct the codec by name
    (e.g. ``Comms("int8", block=128)``)."""

    def __init__(self, compressor: CompressorLike = None, *,
                 bucket: bool = True, **codec_kwargs):
        self.codec = make_compressor(compressor, **codec_kwargs)
        self.bucket = bool(bucket)

    def __repr__(self):
        return f"Comms({self.codec!r}, bucket={self.bucket})"

    # -- payload layout -----------------------------------------------------
    def _payloads(self, tree):
        """tree -> (payload pytree the codec sees, FlatBucket | None)."""
        if not self.bucket:
            return tree, None
        fb = FlatBucket.plan(tree)
        return fb.flatten(tree), fb

    # -- engine state -------------------------------------------------------
    def init_state(self, params) -> Optional[Any]:
        """Per-worker error-feedback residual (zeros), or None for
        stateless codecs.  Residuals are f32 payload-shaped, so they ride
        the same worker-axis sharding as params."""
        if not self.codec.stateful:
            return None
        payload, _ = self._payloads(params)
        return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                            payload)

    # -- the sync ------------------------------------------------------------
    def sync(self, tree, reduce_fn: Callable[[Any], Any],
             residual: Optional[Any] = None) -> Tuple[Any, Optional[Any]]:
        """Aggregate ``tree`` through the wire: bucketize, codec-roundtrip
        each worker's payload (+ error feedback when ``residual`` is
        threaded), reduce the decoded payloads with ``reduce_fn``, restore
        the tree.  Returns (aggregated tree, new residual)."""
        payload, fb = self._payloads(tree)
        leaves, tdef = jax.tree.flatten(payload)
        if residual is None:
            rleaves = [None] * len(leaves)
        else:
            rleaves = tdef.flatten_up_to(residual)
        pairs = [self.codec.roundtrip(x, r) for x, r in zip(leaves, rleaves)]
        sent = tdef.unflatten([s for s, _ in pairs])
        new_res = None
        if self.codec.stateful and residual is not None:
            new_res = tdef.unflatten([r for _, r in pairs])
        reduced = reduce_fn(sent)
        out = fb.unflatten(reduced) if fb is not None else reduced
        return out, new_res

    # -- accounting ----------------------------------------------------------
    def payload_spec(self, params) -> Tuple[Tuple[WireArray, ...], int]:
        """Static (wire arrays, element count) for ONE worker's payload —
        the :class:`~repro.comms.wire.WireStats` input."""
        arrays = []
        total = 0
        if self.bucket:
            fb = FlatBucket.plan(params)
            for key in sorted(fb.lengths):
                n = fb.lengths[key]
                total += n
                for a in self.codec.wire_spec(n, fb.dtypes[key]):
                    arrays.append(WireArray(f"{key}.{a.name}", a.shape,
                                            a.dtype))
        else:
            for i, leaf in enumerate(jax.tree.leaves(params)):
                n = int(np.prod(np.shape(leaf)[1:], dtype=np.int64))
                total += n
                for a in self.codec.wire_spec(n, leaf.dtype):
                    arrays.append(WireArray(f"leaf{i}.{a.name}", a.shape,
                                            a.dtype))
        return tuple(arrays), total


CommsLike = Union[str, Compressor, Comms, None]


def make_comms(spec: CommsLike = None, **kwargs) -> Optional[Comms]:
    """Resolve the ``HSGD(..., comms=...)`` argument: None = off (default,
    bitwise-identical to the pre-comms engine), a codec name or Compressor
    = bucketized comms with that codec, or a ready :class:`Comms`."""
    if spec is None and not kwargs:
        return None
    if isinstance(spec, Comms):
        assert not kwargs, "kwargs only apply when constructing by name"
        return spec
    return Comms(spec, **kwargs)
