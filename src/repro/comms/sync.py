"""The resolved comms plan: bucketization switch + codec, bound per engine.

``HSGD(..., comms=...)`` resolves its argument through :func:`make_comms`
into a :class:`Comms` (or None = comms off, the bitwise-identical default
path).  A ``Comms`` owns HOW a sync payload crosses the wire — fused
flat-buffer buckets or raw leaves, and through which codec — while staying
agnostic to WHO reduces it: executors pass their own ``reduce_fn`` (the
topology's segment-mean under sim, the aggregator's named-axis collective
under mesh), so one comms plan serves both backends and the aggregator's
``encode``/mean/``decode`` contract is untouched.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.comms.codecs import Compressor, CompressorLike, make_compressor
from repro.comms.flat import FlatBucket
from repro.comms.wire import WireArray


class Comms:
    """compressor: a codec instance, registry name, or None (identity).
    bucket: fuse the tree into one buffer per dtype before encoding
    (O(dtypes) sync operands); False keeps leaf-wise payloads (O(leaves),
    but still codec-compressed).  wire_reduce: let executors hand eligible
    syncs to the codec's compressed-collective form
    (:meth:`~repro.comms.codecs.Compressor.reduce`) instead of the legacy
    per-worker encode/decode roundtrip; False forces the roundtrip path
    everywhere.  Extra kwargs construct the codec by name (e.g.
    ``Comms("int8", block=128)``)."""

    def __init__(self, compressor: CompressorLike = None, *,
                 bucket: bool = True, wire_reduce: bool = True,
                 **codec_kwargs):
        self.codec = make_compressor(compressor, **codec_kwargs)
        self.bucket = bool(bucket)
        self.wire_reduce = bool(wire_reduce)
        self._plans: Dict[Any, FlatBucket] = {}

    def __repr__(self):
        return f"Comms({self.codec!r}, bucket={self.bucket})"

    # -- payload layout -----------------------------------------------------
    def _plan(self, tree) -> FlatBucket:
        """Treedef-keyed bucket-plan cache: repeated traces of the same
        tree signature (every round body re-traces the sync) hit the
        instance cache instead of re-planning the layout.  The key carries
        shapes/dtypes too — one Comms may serve several engines."""
        leaves, treedef = jax.tree.flatten(tree)
        key = (treedef, tuple((np.shape(l), jnp.dtype(l.dtype).name)
                              for l in leaves))
        fb = self._plans.get(key)
        if fb is None:
            fb = self._plans[key] = FlatBucket.plan(tree)
        return fb

    def _payloads(self, tree):
        """tree -> (payload pytree the codec sees, FlatBucket | None)."""
        if not self.bucket:
            return tree, None
        fb = self._plan(tree)
        return fb.flatten(tree), fb

    # -- engine state -------------------------------------------------------
    def init_state(self, params) -> Optional[Any]:
        """Per-worker error-feedback residual (zeros), or None for
        stateless codecs.  Residuals are f32 payload-shaped, so they ride
        the same worker-axis sharding as params."""
        if not self.codec.stateful:
            return None
        payload, _ = self._payloads(params)
        return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                            payload)

    # -- the sync ------------------------------------------------------------
    def sync(self, tree, reduce_fn: Callable[[Any], Any],
             residual: Optional[Any] = None,
             reduce_mode: Optional[Any] = None) -> Tuple[Any, Optional[Any]]:
        """Aggregate ``tree`` through the wire.  Returns
        (aggregated tree, new residual).

        ``reduce_mode=None`` (legacy): bucketize, codec-roundtrip each
        worker's payload (+ error feedback when ``residual`` is threaded),
        reduce the decoded payloads with ``reduce_fn``, restore the tree.

        ``reduce_mode=<WireOps>``: the compressed-collective path — the
        encoded payload itself is handed to the executor's collective via
        :meth:`~repro.comms.codecs.Compressor.reduce`, so the wire carries
        the codec's wire dtype instead of a decoded f32 round-trip.
        ``reduce_fn`` is unused on this path.

        Layout-free codecs (identity) under an in-array backend skip the
        FlatBucket entirely: packing is pure data movement there — the
        reduce is elementwise-identical either way — so the pack/unpack
        pair would be the only thing the codec adds to the round body."""
        if (reduce_mode is not None and self.codec.layout_free
                and not self.codec.stateful
                and getattr(reduce_mode, "backend", None) == "sim"):
            payload, fb = tree, None
        else:
            payload, fb = self._payloads(tree)
        leaves, tdef = jax.tree.flatten(payload)
        if residual is None:
            rleaves = [None] * len(leaves)
        else:
            rleaves = tdef.flatten_up_to(residual)
        if reduce_mode is not None:
            pairs = [self.codec.reduce(x, reduce_mode, r)
                     for x, r in zip(leaves, rleaves)]
            reduced = tdef.unflatten([s for s, _ in pairs])
        else:
            pairs = [self.codec.roundtrip(x, r)
                     for x, r in zip(leaves, rleaves)]
            reduced = reduce_fn(tdef.unflatten([s for s, _ in pairs]))
        new_res = None
        if self.codec.stateful and residual is not None:
            new_res = tdef.unflatten([r for _, r in pairs])
        out = fb.unflatten(reduced) if fb is not None else reduced
        return out, new_res

    # -- accounting ----------------------------------------------------------
    def payload_spec(self, params) -> Tuple[Tuple[WireArray, ...], int]:
        """Static (wire arrays, element count) for ONE worker's payload —
        the :class:`~repro.comms.wire.WireStats` input."""
        for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
            if np.ndim(leaf) < 1:
                raise ValueError(
                    "payload_spec expects every leaf to carry a leading "
                    f"worker axis; leaf {jax.tree_util.keystr(path)!r} is "
                    "rank-0, so its per-worker element count would be "
                    "miscounted.  Stack worker replicas on axis 0 first.")
        arrays = []
        total = 0
        if self.bucket:
            fb = self._plan(params)
            for key in sorted(fb.lengths):
                n = fb.lengths[key]
                total += n
                for a in self.codec.wire_spec(n, fb.dtypes[key]):
                    arrays.append(WireArray(f"{key}.{a.name}", a.shape,
                                            a.dtype))
        else:
            for i, leaf in enumerate(jax.tree.leaves(params)):
                n = int(np.prod(np.shape(leaf)[1:], dtype=np.int64))
                total += n
                for a in self.codec.wire_spec(n, leaf.dtype):
                    arrays.append(WireArray(f"leaf{i}.{a.name}", a.shape,
                                            a.dtype))
        return tuple(arrays), total


CommsLike = Union[str, Compressor, Comms, None]


def make_comms(spec: CommsLike = None, **kwargs) -> Optional[Comms]:
    """Resolve the ``HSGD(..., comms=...)`` argument: None = off (default,
    bitwise-identical to the pre-comms engine), a codec name or Compressor
    = bucketized comms with that codec, or a ready :class:`Comms`."""
    if spec is None and not kwargs:
        return None
    if isinstance(spec, Comms):
        assert not kwargs, "kwargs only apply when constructing by name"
        return spec
    return Comms(spec, **kwargs)
