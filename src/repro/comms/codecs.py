"""Pluggable wire codecs (``Compressor``) for sync payloads.

A :class:`Compressor` defines the WIRE FORMAT of a payload buffer — what the
collective actually moves — independently of the aggregation rule: the codec
compresses each worker's contribution (a lossy encode/decode round-trip in
the simulator, the literal wire arrays on hardware), then whatever
:class:`~repro.core.aggregators.Aggregator` is installed defines the mean of
the decoded contributions.  Any codec therefore composes with any
aggregator, and with either executor.

Codecs see payloads as ``(rows, ...)`` arrays with a leading worker (or
worker-shard) axis; trailing dims are flattened internally, so the same
codec handles fused :class:`~repro.comms.flat.FlatBucket` buffers and raw
leaves.  The int8 and sign codecs run the Pallas kernels in
:mod:`repro.kernels.comms` (compiled on TPU, interpret elsewhere);
``topk`` is a jnp-level sparsifier whose error-feedback residual the engine
carries in ``HSGDState.comms`` (1-bit SGD / DGC style: what compression
drops this sync is re-injected next sync, so the error stays bounded
instead of accumulating).

Registry mirrors the aggregator/executor/topology ones:
:func:`make_compressor` / :func:`register_compressor`.
"""
from __future__ import annotations

import abc
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.comms.wire import WireArray
from repro.kernels import comms as _kernels
from repro.kernels.ops import _interpret_default


class Compressor(abc.ABC):
    """Wire codec: encode a payload to its wire arrays, decode them back.

    stateful=True codecs carry a per-worker error-feedback residual (engine
    state); for them :meth:`roundtrip` adds the residual before encoding and
    returns the new residual alongside the decoded payload.
    """

    name = "compressor"
    stateful = False
    # True when :meth:`reduce` implements the compressed-collective form —
    # the executor then hands the codec its WireOps instead of running the
    # legacy encode -> reduce_fn(decoded f32) -> decode roundtrip
    wire_reduce = False
    # True when :meth:`reduce` is elementwise-independent of payload layout
    # (no cross-element block statistics), so bucketizing the tree changes
    # nothing but memory movement.  In-array backends then elide the
    # FlatBucket pack/unpack pair from the round body entirely — this is
    # what makes the identity codec wall-clock-free under sim.
    layout_free = False

    @abc.abstractmethod
    def encode(self, x: jax.Array) -> Dict[str, jax.Array]:
        """(rows, ...) payload -> the arrays that cross the wire."""

    @abc.abstractmethod
    def decode(self, wire: Dict[str, jax.Array], like: jax.Array) -> jax.Array:
        """Wire arrays -> f32 payload shaped like ``like``."""

    @abc.abstractmethod
    def wire_spec(self, length: int, dtype) -> Tuple[WireArray, ...]:
        """Static wire arrays for ONE worker's ``length``-element payload of
        ``dtype`` — the input to :class:`~repro.comms.wire.WireStats`."""

    def roundtrip(self, x: jax.Array, residual: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, Optional[jax.Array]]:
        """The simulator's view of the wire: what the receiver reconstructs
        from this worker's payload, plus the updated error-feedback residual
        (None for stateless codecs or when no residual is threaded)."""
        if residual is None:
            u = x
        else:
            u = x.astype(residual.dtype) + residual
        sent = self.decode(self.encode(u), u)
        if residual is None or not self.stateful:
            return sent.astype(x.dtype), None
        return sent.astype(x.dtype), (u - sent.astype(u.dtype))

    def reduce(self, x: jax.Array, ops,
               residual: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, Optional[jax.Array]]:
        """The compressed collective: aggregate ``x`` THROUGH the encoded
        wire form using ``ops`` (a :mod:`repro.comms.reduce` WireOps) so the
        reduction operand carries the wire dtype, not decoded f32.  Returns
        (aggregated payload, new error-feedback residual or None).  Only
        meaningful when ``wire_reduce`` is True."""
        raise NotImplementedError(
            f"{type(self).__name__} has no compressed-collective form "
            f"(wire_reduce={self.wire_reduce})")

    def lowered_sync_ops(self, backend: str) -> Optional[int]:
        """How many counted aggregation ops ONE :meth:`reduce` call lowers
        to per payload buffer — in-array f32/i32 reduces under ``"sim"``,
        named-axis collectives under ``"mesh"`` (the R1 prediction).  None
        when no exact count exists."""
        return None

    def __repr__(self):
        return f"{type(self).__name__}()"


def _rows(x: jax.Array) -> jax.Array:
    return x.reshape(x.shape[0], -1)


class IdentityCompressor(Compressor):
    """No compression — the payload crosses the wire at its own dtype.
    Useful as the FlatBucket-only configuration (fused buffers, exact
    values) and as the accounting baseline."""

    name = "identity"
    wire_reduce = True
    layout_free = True  # plain mean: bucket layout cannot change a value

    def encode(self, x):
        return {"value": x}

    def decode(self, wire, like):
        return wire["value"]

    def reduce(self, x, ops, residual=None):
        # no wire format to exploit: one group mean per buffer, with no
        # encode/decode bookkeeping around it (the identity-tax fix)
        return ops.mean(x), None

    def lowered_sync_ops(self, backend):
        return 1

    def wire_spec(self, length, dtype):
        return (WireArray("value", (length,), jnp.dtype(dtype).name),)


class Int8Compressor(Compressor):
    """Per-block symmetric int8 (block max-scale): ~4x fewer bytes than f32
    (1 byte/element + one f32 scale per ``block``)."""

    name = "int8"
    wire_reduce = True

    def __init__(self, block: int = 256):
        self.block = int(block)

    def encode(self, x):
        q, scale = _kernels.int8_quantize(
            _rows(x), block=self.block, interpret=_interpret_default())
        return {"q": q, "scale": scale}

    def decode(self, wire, like):
        y = _kernels.int8_dequantize(
            wire["q"], wire["scale"], block=self.block,
            interpret=_interpret_default())
        return y.reshape(like.shape)

    def reduce(self, x, ops, residual=None):
        """The int8 compressed allreduce: share one group-max scale per
        block (a max reduce of block stats), quantize against it, and SUM
        THE INT8 PAYLOADS in an int32 accumulator — the only elementwise
        reduction carries the widened wire dtype, exactly (|sum q| <=
        127 * members < 2^31, and < 2^24 for any plausible group, so the
        f32 decode is exact too).  One decode at the end: qsum * scale /
        count."""
        x2 = _rows(x).astype(jnp.float32)
        r, c = x2.shape
        nb = -(-c // self.block)
        pad = nb * self.block - c
        amax = jnp.pad(jnp.abs(x2), ((0, 0), (0, pad))) \
            .reshape(r, nb, self.block).max(axis=-1)          # (r, nb)
        scale = ops.max(amax) / 127.0                          # group scale
        q = _kernels.int8_scale_quantize(
            x2, scale, block=self.block, interpret=_interpret_default())
        qsum = ops.sum(q.astype(jnp.int32))
        y = (jnp.pad(qsum.astype(jnp.float32), ((0, 0), (0, pad)))
             .reshape(r, nb, self.block) * scale[..., None]) \
            .reshape(r, nb * self.block)[:, :c]
        y = y / ops.count()
        return y.reshape(x.shape).astype(x.dtype), None

    def lowered_sync_ops(self, backend):
        # mesh: pmax on the scales + psum on the int32 payload; sim: the
        # reshape-max of block stats is not a counted aggregation reduce,
        # leaving only the int32 worker-axis sum
        return 2 if backend == "mesh" else 1

    def wire_spec(self, length, dtype):
        nb = -(-length // self.block)
        return (WireArray("q", (length,), "int8"),
                WireArray("scale", (nb,), "float32"))

    def __repr__(self):
        return f"Int8Compressor(block={self.block})"


class SignCompressor(Compressor):
    """1-bit sign compression (1-bit SGD): 8 signs per uint8 plus a
    per-block ``mean|x|`` magnitude — ~32x fewer bytes than f32 at the
    default block.  Lossy by design; compose with error feedback at the
    optimizer level or accept the trajectory change (tested finite)."""

    name = "sign"
    wire_reduce = True

    def __init__(self, block: int = 1024):
        assert block % 8 == 0, block
        self.block = int(block)

    def encode(self, x):
        bits, scale = _kernels.sign_pack(
            _rows(x), block=self.block, interpret=_interpret_default())
        return {"bits": bits, "scale": scale}

    def decode(self, wire, like):
        size = _rows(like).shape[1]
        y = _kernels.sign_unpack(
            wire["bits"], wire["scale"], size=size, block=self.block,
            interpret=_interpret_default())
        return y.reshape(like.shape)

    def reduce(self, x, ops, residual=None):
        """The sign compressed reduce: the packed-uint8 payload crosses the
        wire as-is (``ops.gathered``), the receive side unpacks bits, takes
        the popcount/majority vote in int32, and scales by the group-mean
        magnitude — the aggregate ``s_bar * (#pos - #neg) / count`` per
        element.  No f32 dense payload ever hits the collective."""
        x2 = _rows(x)
        c = x2.shape[1]
        block = self.block
        bits, scale = _kernels.sign_pack(
            x2, block=block, interpret=_interpret_default())

        def fuse(bits_g, scale_g, wmask):
            # member axis at -2 (WireOps.gathered contract)
            from repro.core.aggregators import denominator_floor
            b = bits_g.astype(jnp.int32)
            shift = jnp.arange(8, dtype=jnp.int32)
            unpacked = (b[..., None] >> shift) & 1
            unpacked = unpacked.reshape(b.shape[:-1] + (-1,))[..., :c]
            if wmask is None:
                votes = unpacked.sum(axis=-2)                  # i32 reduce
                count = float(b.shape[-2])                     # static
                ssum = scale_g.sum(axis=-2)
            else:
                votes = (unpacked * wmask.astype(jnp.int32)[..., None]) \
                    .sum(axis=-2)
                count = jnp.maximum(wmask.sum(axis=-1, keepdims=True),
                                    denominator_floor(jnp.float32))
                ssum = (scale_g * wmask[..., None]).sum(axis=-2)
            sgnsum = 2.0 * votes.astype(jnp.float32) - count   # #pos - #neg
            sbar = ssum / count                                # mean scale
            per = jnp.repeat(sbar, block, axis=-1)[..., :c]
            return per * sgnsum / count

        out = ops.gathered(fuse, bits, scale)
        return out.reshape(x.shape).astype(x.dtype), None

    def lowered_sync_ops(self, backend):
        # mesh: all_gather of bits + all_gather of scales; sim: the i32
        # vote sum + the f32 scale sum over the member axis
        return 2

    def wire_spec(self, length, dtype):
        # the kernel pads bits to whole blocks for layout, but only
        # ceil(length/8) bytes carry information — that is what crosses
        # the wire
        nb = -(-length // self.block)
        return (WireArray("bits", (-(-length // 8),), "uint8"),
                WireArray("scale", (nb,), "float32"))

    def __repr__(self):
        return f"SignCompressor(block={self.block})"


class TopKCompressor(Compressor):
    """Top-k magnitude sparsification with error feedback (Deep Gradient
    Compression): each sync ships the k = ``rate * length`` largest-|x|
    entries as (value, index) pairs; everything dropped is carried in the
    per-worker residual and re-injected at the next sync, so the
    compression error stays O(1) instead of accumulating."""

    name = "topk"
    stateful = True
    wire_reduce = True

    def __init__(self, rate: float = 1 / 16):
        assert 0 < rate <= 1, rate
        self.rate = float(rate)

    def _k(self, length: int) -> int:
        return max(1, min(length, int(round(self.rate * length))))

    def encode(self, x):
        x2 = _rows(x).astype(jnp.float32)
        k = self._k(x2.shape[1])
        _, idx = jax.lax.top_k(jnp.abs(x2), k)
        vals = jnp.take_along_axis(x2, idx, axis=1)
        return {"values": vals, "indices": idx.astype(jnp.int32)}

    def decode(self, wire, like):
        rows = like.shape[0]
        length = _rows(like).shape[1]
        out = jnp.zeros((rows, length), jnp.float32)
        r = jnp.arange(rows)[:, None]
        out = out.at[r, wire["indices"]].set(wire["values"])
        return out.reshape(like.shape)

    def reduce(self, x, ops, residual=None):
        """The top-k compressed collective: error feedback and the sparse
        encode stay local and REPLICATE :meth:`roundtrip`'s casts exactly
        (so residual trajectories match the legacy path bitwise); the
        (values, indices) payload then rides ``ops.sparse_mean`` — a ragged
        all-gather + fused Pallas decode-reduce on the mesh, the bitwise
        dense group mean under sim."""
        if residual is None:
            u = x
        else:
            u = x.astype(residual.dtype) + residual
        wire = self.encode(u)
        sent = self.decode(wire, u)
        new_res = None
        if residual is not None:
            new_res = u - sent.astype(u.dtype)
        out = ops.sparse_mean(wire["values"], wire["indices"],
                              sent.astype(x.dtype))
        return out.astype(x.dtype).reshape(x.shape), new_res

    def lowered_sync_ops(self, backend):
        # mesh: all_gather of values + all_gather of indices (the fused
        # decode-reduce is kernel-internal); sim: one dense f32 group mean
        return 2 if backend == "mesh" else 1

    def wire_spec(self, length, dtype):
        k = self._k(length)
        return (WireArray("values", (k,), "float32"),
                WireArray("indices", (k,), "int32"))

    def __repr__(self):
        return f"TopKCompressor(rate={self.rate:g})"


# ---------------------------------------------------------------------------
# registry — the single construction path (mirrors make_aggregator et al.)
# ---------------------------------------------------------------------------
COMPRESSORS = {
    "identity": IdentityCompressor,
    "none": IdentityCompressor,
    "int8": Int8Compressor,
    "q8": Int8Compressor,
    "sign": SignCompressor,
    "1bit": SignCompressor,
    "topk": TopKCompressor,
}

CompressorLike = Union[str, Compressor, None]


def make_compressor(spec: CompressorLike = None, **kwargs) -> Compressor:
    """Resolve a compressor from an instance, a registry name, or None
    (-> IdentityCompressor, exact values at full payload bytes)."""
    if isinstance(spec, Compressor):
        if kwargs:
            raise ValueError(
                f"kwargs {sorted(kwargs)} only apply when constructing by "
                f"name; got the instance {spec!r}")
        return spec
    if spec is None:
        return IdentityCompressor(**kwargs)
    name = spec.lower()
    if name not in COMPRESSORS:
        raise KeyError(f"unknown compressor {spec!r}; "
                       f"known: {sorted(COMPRESSORS)}")
    return COMPRESSORS[name](**kwargs)


def register_compressor(name: str, cls) -> None:
    COMPRESSORS[name.lower()] = cls
