"""Chrome-trace-event / Perfetto export of a training run's timeline.

:class:`TraceRecorder` collects trace events while the engine runs —
per-worker compute spans and barrier waits from the
:class:`~repro.runtime.SimClock` hooks, per-level sync spans annotated with
wire bytes and drop counts, and divergence counter tracks from the drained
in-graph probes — and serializes them in the Chrome trace-event JSON object
format (``{"traceEvents": [...]}``), which Perfetto and ``chrome://tracing``
open directly.

Track layout (pid/tid are just track labels in this format):

* pid 0 ``workers`` — one tid per worker: compute spans (``X``), barrier
  waits (``X``, name ``wait Lℓ``);
* pid 1 ``barriers`` — one tid per hierarchy level: each sync event's link
  span, args carrying ``payload_bytes`` / ``level`` / ``dropped``;
* pid 2 ``probes``   — counter tracks (``C``): one series per divergence
  channel, emitted at the probe's sync step.

Timestamps are microseconds (the format's unit).  With a runtime model
bound they are simulated seconds × 1e6; without one the recorder falls
back to step-index time (1 step = 1 "second") so traces stay well-formed
— the README quickstart documents both.

:func:`validate_trace` is the schema check CI and the tests run over every
exported trace: object-format envelope, required per-event fields, known
phases, non-negative timestamps/durations.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Mapping, Optional

from repro.obs.bus import SCHEMA_VERSION

_US = 1e6  # seconds -> microseconds (the trace-event unit)

# phases this exporter emits (subset of the trace-event format)
_PHASES = ("X", "i", "C", "M")


class TraceRecorder:
    """Accumulates trace events; hand one to ``run_rounds(..., trace=...)``
    (and it is threaded into the runtime clock automatically)."""

    def __init__(self):
        self.events: List[Dict[str, Any]] = []
        self._named: set = set()

    # -- track naming --------------------------------------------------------
    def name_process(self, pid: int, name: str) -> None:
        key = ("p", pid)
        if key in self._named:
            return
        self._named.add(key)
        self.events.append({"name": "process_name", "ph": "M", "pid": pid,
                            "tid": 0, "args": {"name": name}})

    def name_thread(self, pid: int, tid: int, name: str) -> None:
        key = ("t", pid, tid)
        if key in self._named:
            return
        self._named.add(key)
        self.events.append({"name": "thread_name", "ph": "M", "pid": pid,
                            "tid": tid, "args": {"name": name}})

    # -- event emitters (ts/dur in SECONDS; converted here) ------------------
    def complete(self, name: str, ts_s: float, dur_s: float, *, pid: int,
                 tid: int, args: Optional[Mapping] = None) -> None:
        ev = {"name": name, "ph": "X", "ts": round(ts_s * _US, 3),
              "dur": round(max(dur_s, 0.0) * _US, 3), "pid": pid, "tid": tid}
        if args:
            ev["args"] = dict(args)
        self.events.append(ev)

    def instant(self, name: str, ts_s: float, *, pid: int, tid: int,
                args: Optional[Mapping] = None) -> None:
        ev = {"name": name, "ph": "i", "ts": round(ts_s * _US, 3),
              "pid": pid, "tid": tid, "s": "t"}
        if args:
            ev["args"] = dict(args)
        self.events.append(ev)

    def counter(self, name: str, ts_s: float, values: Mapping[str, float], *,
                pid: int) -> None:
        self.events.append({"name": name, "ph": "C",
                            "ts": round(ts_s * _US, 3), "pid": pid, "tid": 0,
                            "args": {k: float(v) for k, v in values.items()}})

    # -- the engine-facing convenience hooks ---------------------------------
    def compute_span(self, worker: int, ts_s: float, dur_s: float) -> None:
        self.name_process(0, "workers")
        self.name_thread(0, worker, f"worker {worker}")
        self.complete("compute", ts_s, dur_s, pid=0, tid=worker)

    def wait_span(self, worker: int, level: int, ts_s: float,
                  dur_s: float) -> None:
        self.name_process(0, "workers")
        self.name_thread(0, worker, f"worker {worker}")
        self.complete(f"wait L{level}", ts_s, dur_s, pid=0, tid=worker)

    def sync_span(self, level: int, ts_s: float, dur_s: float,
                  *, payload_bytes: int = 0, dropped: int = 0,
                  extra: Optional[Mapping] = None) -> None:
        self.name_process(1, "barriers")
        self.name_thread(1, level, f"L{level}")
        args = {"level": level, "payload_bytes": int(payload_bytes),
                "dropped": int(dropped)}
        if extra:
            args.update(extra)
        self.complete(f"sync L{level}", ts_s, dur_s, pid=1, tid=level,
                      args=args)

    def divergences(self, step: int, level: int, ts_s: float,
                    values: Mapping[str, float]) -> None:
        self.name_process(2, "probes")
        self.counter("divergence", ts_s, values, pid=2)
        self.instant(f"probe t={step}", ts_s, pid=1, tid=level,
                     args={"step": step, **{k: float(v)
                                            for k, v in values.items()}})

    # -- serialization -------------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {"exporter": "repro.obs",
                          "schema_version": SCHEMA_VERSION},
        }

    def save(self, path) -> None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.to_json(), f, indent=1)


def validate_trace(obj) -> List[str]:
    """Schema-check a trace (parsed JSON object or a TraceRecorder).
    Returns the list of violations (empty = valid Chrome-trace-event
    object format, as this exporter emits it)."""
    if isinstance(obj, TraceRecorder):
        obj = obj.to_json()
    errors: List[str] = []
    if not isinstance(obj, Mapping):
        return [f"trace must be a JSON object, got {type(obj).__name__}"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["trace object lacks a 'traceEvents' list"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, Mapping):
            errors.append(f"{where}: not an object")
            continue
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                errors.append(f"{where}: missing required field {field!r}")
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"{where}: unknown phase {ph!r} "
                          f"(exporter emits {_PHASES})")
            continue
        if ph != "M" and "ts" not in ev:
            errors.append(f"{where}: {ph!r} event missing 'ts'")
        if "ts" in ev and not (isinstance(ev["ts"], (int, float))
                               and ev["ts"] >= 0):
            errors.append(f"{where}: 'ts' must be a non-negative number")
        if ph == "X":
            if "dur" not in ev:
                errors.append(f"{where}: complete event missing 'dur'")
            elif not (isinstance(ev["dur"], (int, float)) and ev["dur"] >= 0):
                errors.append(f"{where}: 'dur' must be a non-negative number")
        if ph == "C" and not isinstance(ev.get("args"), Mapping):
            errors.append(f"{where}: counter event needs numeric 'args'")
    return errors
