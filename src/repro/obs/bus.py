"""The metrics bus: one typed, versioned schema for every telemetry channel.

Before this module the repo's telemetry was an ad-hoc union of dict keys —
``run_rounds`` history carried the loss-aux metrics plus ``wire_bytes`` /
``sim_time_s`` / ``sim_sync_s``, ``launch/train.py`` emitted its own JSONL
shape, and the benchmarks theirs — with nothing checking that a producer's
key still meant what a consumer expected.  The bus is that check:

* :class:`MetricSpec` declares one channel — exact name or fnmatch pattern
  (``div_up_L*``), value kind (scalar / int / mapping), producing layer —
  and :func:`register_metric` puts it in the process-wide registry;
* :func:`validate_record` lints one per-step record against the registry:
  a known channel carrying the wrong kind is always an error; unknown keys
  are errors only under ``strict=True`` (``run_rounds`` validates leniently
  so user ``eval_fn`` extras pass through; ``launch/train.py`` and the
  benchmarks validate their own fully-registered records strictly);
* ``SCHEMA_VERSION`` stamps exported artifacts (train JSONL header, trace
  metadata, BENCH_obs.json) so downstream tooling can detect shape changes.

Every channel the engine emits today is pre-registered below; new
subsystems register theirs at import time (the registry is additive —
re-registering the same name needs ``overwrite=True``).
"""
from __future__ import annotations

import dataclasses
import numbers
from fnmatch import fnmatch
from typing import Dict, List, Mapping, Optional, Tuple

SCHEMA_VERSION = 1

# value kinds a channel may declare
_KINDS = ("scalar", "int", "mapping")


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One telemetry channel.  ``name`` may be an fnmatch pattern so one
    spec covers a per-level family (``div_up_L*``)."""
    name: str
    kind: str = "scalar"        # "scalar" | "int" | "mapping"
    source: str = "engine"      # producing layer (engine/probe/comms/...)
    units: str = ""
    doc: str = ""

    def __post_init__(self):
        assert self.kind in _KINDS, self
        assert self.name, self

    def matches(self, key: str) -> bool:
        return key == self.name or fnmatch(key, self.name)

    def check(self, value) -> Optional[str]:
        """None if ``value`` fits this channel's kind, else the complaint."""
        if self.kind == "mapping":
            if not isinstance(value, Mapping):
                return f"expected a mapping, got {type(value).__name__}"
        elif self.kind == "int":
            if isinstance(value, bool) or \
                    not isinstance(value, numbers.Integral):
                return f"expected an integer, got {type(value).__name__}"
        elif not isinstance(value, numbers.Real) or isinstance(value, bool):
            return f"expected a real scalar, got {type(value).__name__}"
        return None


_REGISTRY: Dict[str, MetricSpec] = {}


def register_metric(spec: MetricSpec, *, overwrite: bool = False) -> MetricSpec:
    if not overwrite and spec.name in _REGISTRY:
        raise ValueError(f"metric {spec.name!r} is already registered "
                         f"({_REGISTRY[spec.name]}); pass overwrite=True "
                         f"to replace it")
    _REGISTRY[spec.name] = spec
    return spec


def registered_metrics() -> Tuple[MetricSpec, ...]:
    return tuple(_REGISTRY[k] for k in sorted(_REGISTRY))


def spec_for(key: str) -> Optional[MetricSpec]:
    """The spec covering ``key``: exact name first, then the first (sorted)
    matching pattern."""
    spec = _REGISTRY.get(key)
    if spec is not None:
        return spec
    for name in sorted(_REGISTRY):
        if _REGISTRY[name].matches(key):
            return _REGISTRY[name]
    return None


def validate_record(rec: Mapping, *, strict: bool = False) -> List[str]:
    """Lint one telemetry record.  Returns the list of complaints (empty =
    valid).  Kind mismatches on registered channels always complain;
    unregistered keys only under ``strict``."""
    errors: List[str] = []
    for key, value in rec.items():
        spec = spec_for(key)
        if spec is None:
            if strict:
                errors.append(f"unregistered metric {key!r}")
            continue
        err = spec.check(value)
        if err is not None:
            errors.append(f"{key}: {err} (channel {spec.name!r}, "
                          f"kind {spec.kind})")
    return errors


# -- the engine's pre-registered channels ------------------------------------
for _spec in (
    MetricSpec("t", "int", "engine", "step", "1-indexed step number"),
    MetricSpec("step", "int", "launch", "step", "JSONL step number"),
    MetricSpec("ce", "scalar", "engine", "nats",
               "per-step training cross-entropy (worker mean)"),
    MetricSpec("loss", "scalar", "launch", "nats", "eval loss at w̄"),
    MetricSpec("acc", "scalar", "launch", "", "eval accuracy at w̄"),
    MetricSpec("lvl", "int", "launch", "level",
               "sync level fired after this step (absent/None between syncs)"),
    MetricSpec("grad_norm", "scalar", "probe", "l2",
               "worker-mean gradient l2 norm (Metrics.grad_norm channel)"),
    MetricSpec("wire_bytes", "int", "comms", "bytes",
               "bytes this step's sync moved (0 between syncs)"),
    MetricSpec("wire_cum_bytes", "int", "comms", "bytes",
               "cumulative wire bytes (train JSONL)"),
    MetricSpec("sim_time_s", "scalar", "runtime", "s",
               "cumulative simulated makespan"),
    MetricSpec("sim_sync_s", "mapping", "runtime", "s/level",
               "cumulative per-level barrier link seconds"),
    MetricSpec("dropped", "int", "runtime", "workers",
               "workers dropped from this step's sync (0 = full barrier)"),
    MetricSpec("div_global", "scalar", "probe", "param²",
               "global parameter divergence at this step's sync event"),
    MetricSpec("div_up_L*", "scalar", "probe", "param²",
               "upward divergence between level-ℓ subtree means (eq. 10)"),
    MetricSpec("div_down_L*", "scalar", "probe", "param²",
               "mean downward divergence within level-ℓ subtrees (eq. 10)"),
    MetricSpec("divergence", "mapping", "launch", "param²/level",
               "host-oracle gradient divergences (all_divergences)"),
    MetricSpec("elapsed_s", "scalar", "launch", "s", "wall-clock elapsed"),
    MetricSpec("round", "int", "population", "round",
               "1-indexed sampling-round number (population regime)"),
    MetricSpec("participation", "mapping", "population", "clients",
               "per-round sampled-participation summary: k, population, "
               "cells, active, stale_slots, reseen, unique"),
):
    register_metric(_spec)
del _spec
