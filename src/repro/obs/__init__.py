"""repro.obs — the observability layer: in-graph probes, metrics bus, traces.

Three parts (DESIGN.md §7 is the full contract):

* **probes** (:mod:`.probes`) — ``HSGD(..., metrics="on")`` carries a
  :class:`MetricBuffer` in the training state and pushes the paper's
  per-level parameter divergences (eq. (10): global = upward + downward)
  at every sync event, ON device, inside the jitted round body; drained in
  one transfer at eval boundaries.  ``metrics=None`` (default) is
  bitwise-identical to no observability at all.
* **bus** (:mod:`.bus`) — the typed channel registry
  (:func:`register_metric` / :class:`MetricSpec`) and record linter
  (:func:`validate_record`) every telemetry producer emits through.
* **trace** (:mod:`.trace`) — :class:`TraceRecorder` exports the run as
  Chrome-trace-event/Perfetto JSON (``python -m repro.obs`` is the CLI;
  ``run_rounds(..., trace=recorder)`` the engine hook).
"""
from repro.obs.bus import (SCHEMA_VERSION, MetricSpec, register_metric,
                           registered_metrics, spec_for, validate_record)
from repro.obs.probes import MetricBuffer, Metrics, MetricsLike, make_metrics
from repro.obs.trace import TraceRecorder, validate_trace

__all__ = [
    "Metrics", "MetricsLike", "MetricBuffer", "make_metrics",
    "MetricSpec", "SCHEMA_VERSION", "register_metric", "registered_metrics",
    "spec_for", "validate_record",
    "TraceRecorder", "validate_trace",
]
