"""In-graph divergence probes: the paper's telemetry measured ON device.

The paper's whole analysis runs through the eq. (10) partition — global
parameter divergence = upward (between level-ℓ subtree means) + downward
(within subtrees) — yet until now the repo could only measure it out of
band, in a separate host pass over recomputed gradients.  This module puts
the measurement inside the jitted round body instead:

* a :class:`Metrics` plan (``HSGD(..., metrics=...)``, resolved through
  :func:`make_metrics` exactly like comms/runtime: None = off, the
  bitwise-identical default) decides WHAT is probed — per-level parameter
  divergences at every :class:`~repro.core.topology.SyncEvent`, and a
  per-step ``grad_norm`` channel folded into the local-update metrics;
* a :class:`MetricBuffer` ring (carried in ``HSGDState.metrics`` alongside
  ``comms``) accumulates one probe row per sync event on device, so the
  round body stays host-free (analysis rule R3) — ``run_rounds`` drains it
  in ONE device→host transfer at eval boundaries / before overflow / at the
  end, and reconstructs each row's (step, level) from the static schedule;
* the probe itself has two lowerings that the executors keep in lockstep
  with their aggregation paths: :meth:`Metrics.sim_row_fn` evaluates the
  fused eq. (10) partition (:func:`repro.core.divergence.
  partition_divergences`, tested against the naive host-oracle formulas)
  on the in-array worker block (vmap backend), :meth:`Metrics.mesh_row_fn` is the
  named-axis form — per-level ``pmean`` group means plus one final stacked
  pmean, L+2 collectives per sync for L internal levels (shard_map
  backend).  Sim and mesh values agree to accumulation rounding; the
  eq. (10) identity ``up_ℓ + down_ℓ == global`` holds per level (tested).

The probe measures PARAM divergences on the pre-aggregation worker params
(the states already resident when the sync fires) — the live counterpart of
the paper's analysis object, at zero extra passes.  Gradient-divergence
telemetry at a common point stays available via the host path
(:func:`repro.core.divergence.per_worker_grads`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.divergence import partition_divergences_tree


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MetricBuffer:
    """On-device ring of probe rows: ``rows`` is (capacity, k) float32,
    ``count`` the number of pushes since the last drain.  Rows don't carry
    their step/level — the drain reconstructs both from the static schedule
    (one fewer on-device write per push, and nothing to keep replicated
    under the mesh executor beyond the rows themselves)."""
    rows: jax.Array    # (capacity, k) f32
    count: jax.Array   # scalar int32

    @classmethod
    def zeros(cls, capacity: int, k: int) -> "MetricBuffer":
        return cls(jnp.zeros((capacity, max(k, 1)), jnp.float32),
                   jnp.zeros((), jnp.int32))

    @property
    def capacity(self) -> int:
        return self.rows.shape[0]

    def push(self, row: jax.Array) -> "MetricBuffer":
        """Append one probe row (jit-safe; wraps at capacity — the engine
        drains before that ever happens)."""
        idx = self.count % self.rows.shape[0]
        row = jnp.reshape(row, (-1,)).astype(self.rows.dtype)
        rows = jax.lax.dynamic_update_index_in_dim(self.rows, row, idx, 0)
        return MetricBuffer(rows, self.count + 1)

    def reset(self) -> "MetricBuffer":
        """Post-drain buffer: same storage, count back to zero (rows are
        overwritten by later pushes; no device work to clear them)."""
        return MetricBuffer(self.rows, jnp.zeros((), jnp.int32))


@dataclasses.dataclass(frozen=True)
class Metrics:
    """The resolved observability plan, bound per engine
    (``HSGD(..., metrics=...)`` through :func:`make_metrics`).

    divergences: push the per-level divergence row at every sync event.
    grad_norm:   add a per-worker-mean gradient-l2-norm channel to the
                 per-step training metrics (rides the existing metric
                 transfer; no extra device→host traffic).
    capacity:    probe-buffer rows between forced drains.
    """
    divergences: bool = True
    grad_norm: bool = True
    capacity: int = 256

    def __post_init__(self):
        assert self.capacity >= 1, self

    # -- channel layout ------------------------------------------------------
    def levels(self, topology) -> Tuple[int, ...]:
        """The internal levels probed (keys of ``level_groupings``)."""
        return tuple(sorted(topology.level_groupings()))

    def channels(self, topology) -> Tuple[str, ...]:
        """Probe-row layout: global divergence first, then (upward,
        downward) per internal level, matching eq. (10)'s partition."""
        out = ["global"]
        for lvl in self.levels(topology):
            out += [f"up_L{lvl}", f"down_L{lvl}"]
        return tuple(out)

    def history_keys(self, topology) -> Tuple[str, ...]:
        """The per-step history keys the drained rows merge in under."""
        return tuple(f"div_{c}" for c in self.channels(topology))

    def init_buffer(self, topology) -> MetricBuffer:
        return MetricBuffer.zeros(self.capacity,
                                  len(self.channels(topology)))

    # -- the two probe lowerings --------------------------------------------
    def sim_row_fn(self, topology) -> Callable[[Any], jax.Array]:
        """In-array probe for the vmap backend: the fused eq. (10)
        partition evaluated leaf-by-leaf on the (n, ...) worker params
        (:func:`repro.core.divergence.partition_divergences_tree` — one
        pass per leaf plus one group-mean contraction per leaf x level, no
        flatten/concat copy).  Equal to the naive per-term host oracle
        :func:`repro.core.divergence.all_divergences` up to f32
        accumulation rounding (tested)."""
        groupings = topology.level_groupings()
        ordered = [groupings[lvl] for lvl in self.levels(topology)]

        def row(params) -> jax.Array:
            return partition_divergences_tree(params, ordered)

        return row

    def mesh_row_fn(self, topology,
                    rep_axes: Tuple[str, ...]) -> Callable[[Any], jax.Array]:
        """Named-axis probe for the shard_map backend (uniform hierarchies:
        the level-ℓ subtree mean IS ``pmean`` over the mesh axes of levels
        > ℓ).  Per sync: one global-mean pmean, one pmean per internal
        level, and one final pmean of the stacked squared norms — L+2
        collectives, every output fully replicated.  Grouped topologies
        have no per-level axis structure; probe them on the simulator."""
        if getattr(topology, "spec", None) is None:
            raise NotImplementedError(
                f"{type(topology).__name__} has no named-axis level "
                "structure for the divergence probe; run it on the "
                "simulator (HSGD(..., executor='sim')) or disable "
                "divergence probing (Metrics(divergences=False))")
        levels = self.levels(topology)
        assert len(rep_axes) == len(levels) + 1, (rep_axes, levels)

        def row(params) -> jax.Array:
            # this shard's whole replica as one flat f32 vector
            x = jnp.concatenate(
                [jnp.reshape(l, (-1,)).astype(jnp.float32)
                 for l in jax.tree.leaves(params)])
            xbar = jax.lax.pmean(x, rep_axes)
            sq = lambda d: jnp.sum(d * d)
            parts = [sq(x - xbar)]
            for lvl in levels:
                # level-ℓ subtree mean: workers sharing axes[:ℓ] coordinates
                gm = jax.lax.pmean(x, rep_axes[lvl:])
                parts += [sq(gm - xbar), sq(x - gm)]
            # worker means of every squared norm in one stacked collective
            return jax.lax.pmean(jnp.stack(parts), rep_axes)

        return row

    # -- the R6 overhead contract -------------------------------------------
    def op_budget(self, backend: str, topology, n_param_leaves: int) -> int:
        """Max extra aggregation/probe ops a metrics-on round body may add
        vs its metrics-off twin (rule R6; measured by the audit engine).

        mesh: the divergence probe is exactly L+2 collectives per sync
        (L internal levels) and the ``grad_norm`` channel one extra metric
        pmean.  sim: the leaf-by-leaf partition lowers to 3 in-array
        reduces per leaf for the global term (worker mean, squared-norm
        row sum, worker mean of those) and 3 per leaf x level (group-mean
        contraction, squared-norm row sum, weighted sum) — 3·leaves·(1+L)
        — plus one sum-of-squares reduce per param leaf for
        ``grad_norm``."""
        L = len(self.levels(topology))
        budget = 0
        if backend == "mesh":
            if self.divergences:
                budget += L + 2
            if self.grad_norm:
                budget += 1
        else:
            if self.divergences:
                budget += 3 * n_param_leaves * (1 + L)
            if self.grad_norm:
                budget += n_param_leaves + 1
        return budget


MetricsLike = Union[Metrics, str, bool, None]


def make_metrics(spec: MetricsLike = None, **kwargs):
    """Resolve the ``HSGD(..., metrics=...)`` argument: None/False = off
    (the bitwise-identical default — no buffer in the state, no probe in
    the round body, same lowered jaxpr), ``True``/``"on"`` = the default
    :class:`Metrics` plan, or a ready instance."""
    if spec is None or spec is False:
        assert not kwargs, "kwargs only apply when constructing a plan"
        return None
    if isinstance(spec, Metrics):
        assert not kwargs, "kwargs only apply when constructing a plan"
        return spec
    assert spec is True or (isinstance(spec, str) and spec.lower() == "on"), \
        f"metrics must be a Metrics plan, 'on', True or None; got {spec!r}"
    return Metrics(**kwargs)
