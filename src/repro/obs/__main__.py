"""``python -m repro.obs`` — a self-contained observability demo run.

Trains a tiny synthetic H-SGD world (SimpleModel MLP, random batches — no
dataset or benchmark harness imports) with the in-graph probes on and a
simulated runtime clock, then exports the run as a Chrome-trace-event /
Perfetto JSON (load it at https://ui.perfetto.dev or chrome://tracing) and
prints one summary line per sync event with the live eq. (10) partition.

This is the smoke CI runs on both device legs: the trace is validated
against the trace-event schema (:func:`repro.obs.validate_trace`) before
it is written, so a malformed exporter fails the run, not the viewer.

    PYTHONPATH=src python -m repro.obs --out OBS_trace.json
    PYTHONPATH=src python -m repro.obs --backend mesh --levels 3
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp

from repro.core.hsgd import HSGD
from repro.core.topology import HierarchySpec, make_topology
from repro.models.simple import SimpleConfig, SimpleModel
from repro.obs import TraceRecorder, validate_trace
from repro.optim.optimizers import sgd

SPECS = {
    2: HierarchySpec((2, 4), (8, 4)),
    3: HierarchySpec((2, 2, 2), (8, 4, 2)),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="probes-on demo run with Perfetto trace export")
    ap.add_argument("--out", default="OBS_trace.json",
                    help="trace JSON path (default: OBS_trace.json)")
    ap.add_argument("--steps", type=int, default=16,
                    help="training steps (default: 16 = two global periods)")
    ap.add_argument("--levels", type=int, choices=(2, 3), default=3,
                    help="hierarchy depth (default: 3)")
    ap.add_argument("--backend", default="sim", choices=("sim", "mesh"),
                    help="executor (mesh needs one device per worker)")
    ap.add_argument("--runtime", default="0.004",
                    help="simulated seconds per local step for the runtime "
                         "clock ('' disables it; spans then use step-index "
                         "time)")
    args = ap.parse_args(argv)

    spec = SPECS[args.levels]
    if args.backend == "mesh" and len(jax.devices()) < spec.n_workers:
        print(f"mesh backend needs {spec.n_workers} devices, "
              f"have {len(jax.devices())}", file=sys.stderr)
        return 1
    topo = make_topology("uniform", spec=spec)
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=16, hidden=16,
                                     num_classes=4))
    runtime = None
    if args.runtime:
        from repro.runtime import RuntimeModel
        runtime = RuntimeModel(compute_s=float(args.runtime))
    eng = HSGD(model.loss, sgd(0.1), topo, executor=args.backend,
               comms="identity", runtime=runtime, metrics="on")
    state = eng.init(jax.random.PRNGKey(0), model.init)
    n = topo.n

    def batch_fn(t):
        x = jax.random.normal(jax.random.PRNGKey(t), (n, 8, 16))
        return {"x": x, "y": jnp.asarray(jax.random.categorical(
            jax.random.PRNGKey(10_000 + t), jnp.zeros((n, 8, 4))))}

    recorder = TraceRecorder()
    state, hist = eng.run_rounds(state, batch_fn, args.steps,
                                 trace=recorder)

    for rec in hist:
        if "div_global" in rec:
            print(json.dumps({k: round(v, 6) if isinstance(v, float) else v
                              for k, v in rec.items()
                              if k in ("t", "lvl", "wire_bytes")
                              or k.startswith("div_")
                              or k == "grad_norm"}))

    errors = validate_trace(recorder)
    assert not errors, errors
    recorder.save(args.out)
    print(json.dumps({"trace": args.out,
                      "trace_events": len(recorder.events),
                      "steps": args.steps, "backend": args.backend,
                      "sync_records": sum("div_global" in r for r in hist)}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
