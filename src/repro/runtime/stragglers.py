"""Straggler samplers: per-worker compute-time multipliers, step by step.

Heterogeneity is what makes the paper's wall-clock argument interesting —
Castiglia et al.'s multi-level analysis (PAPERS.md) explicitly targets
hierarchical networks whose workers do NOT run in lockstep.  A sampler
answers one question: "how much slower than nominal is worker j at step t?"
as an (n,) multiplier vector (1.0 = nominal speed).

Design invariant — **policy-independent draws**: ``multipliers(t)`` is a
pure function of ``(seed, t)`` (the bursty Markov chain evolves from the
seed as a function of t only, never of what the engine did with earlier
draws).  Two runs over the same schedule therefore see bit-identical
compute times regardless of participation policy, which is what makes
"deadline-elastic is never slower than full-barrier" an exact, assertable
invariant (see :mod:`repro.runtime.clock`) instead of a statistical one.

Three regimes (registry ``STRAGGLERS`` / :func:`make_straggler`):

* ``fixed``     — a fixed random subset of workers is permanently ``factor``
                  times slower (the classic dedicated-slow-node regime);
* ``lognormal`` — i.i.d. per-(worker, step) lognormal jitter with unit mean
                  (heavy-tailed OS/network noise);
* ``bursty``    — a two-state Markov chain per worker (nominal <-> slow),
                  modeling transient contention bursts.
"""
from __future__ import annotations

import abc
from typing import Dict, List, Optional, Union

import numpy as np


def _rng(seed: int, *ctx: int) -> np.random.Generator:
    """Counter-based generator: a fresh, deterministic stream per (seed,
    context) tuple — draws never depend on call order."""
    return np.random.default_rng([0x5712A6, int(seed)] + [int(c) for c in ctx])


class StragglerSampler(abc.ABC):
    """(n, seed)-bound sampler of per-worker compute multipliers."""

    def __init__(self, n: int, seed: int = 0):
        assert n >= 1
        self.n = int(n)
        self.seed = int(seed)

    @abc.abstractmethod
    def multipliers(self, t: int) -> np.ndarray:
        """(n,) positive float64 multipliers for the local update of step
        ``t`` (0-indexed); a pure function of ``(seed, t)``."""

    def rebind(self, n: int, seed: int) -> "StragglerSampler":
        """Same regime, different world (the RuntimeModel carries a template
        sampler; the clock rebinds it to the topology's n and run seed)."""
        return type(self)(n, seed, **self.params())

    def params(self) -> Dict:
        return {}

    def __repr__(self):
        kv = ", ".join(f"{k}={v}" for k, v in self.params().items())
        return f"{type(self).__name__}(n={self.n}, seed={self.seed}" + \
            (f", {kv})" if kv else ")")


class NoStraggler(StragglerSampler):
    """Homogeneous fleet: every worker at nominal speed every step."""

    def multipliers(self, t: int) -> np.ndarray:
        return np.ones(self.n)


class FixedSlowStraggler(StragglerSampler):
    """A seed-chosen fraction of workers is permanently ``factor``x slower."""

    def __init__(self, n: int, seed: int = 0, frac: float = 0.25,
                 factor: float = 4.0):
        super().__init__(n, seed)
        assert 0.0 <= frac <= 1.0 and factor >= 1.0
        self.frac = float(frac)
        self.factor = float(factor)
        k = int(round(self.frac * n))
        slow = _rng(self.seed, 1).choice(n, size=k, replace=False)
        self.slow_set = np.zeros(n, bool)
        self.slow_set[slow] = True

    def params(self) -> Dict:
        return {"frac": self.frac, "factor": self.factor}

    def multipliers(self, t: int) -> np.ndarray:
        return np.where(self.slow_set, self.factor, 1.0)


class LognormalStraggler(StragglerSampler):
    """i.i.d. lognormal jitter per (worker, step), mean exactly 1.0
    (``exp(sigma*z - sigma^2/2)``), so the FLEET's nominal throughput is
    unchanged and only the tail stretches."""

    def __init__(self, n: int, seed: int = 0, sigma: float = 0.5):
        super().__init__(n, seed)
        assert sigma >= 0.0
        self.sigma = float(sigma)

    def params(self) -> Dict:
        return {"sigma": self.sigma}

    def multipliers(self, t: int) -> np.ndarray:
        z = _rng(self.seed, 2, t).standard_normal(self.n)
        return np.exp(self.sigma * z - 0.5 * self.sigma * self.sigma)


class BurstyStraggler(StragglerSampler):
    """Two-state Markov chain per worker: nominal -> slow with ``p_enter``,
    slow -> nominal with ``p_exit``; slow state is ``factor``x.  The chain
    state at step t is computed (and cached) by evolving from t=0 with
    per-step counter-based uniforms, so it is a pure function of (seed, t)
    — never of the call sequence."""

    def __init__(self, n: int, seed: int = 0, p_enter: float = 0.05,
                 p_exit: float = 0.3, factor: float = 6.0):
        super().__init__(n, seed)
        assert 0.0 <= p_enter <= 1.0 and 0.0 < p_exit <= 1.0 and factor >= 1.0
        self.p_enter = float(p_enter)
        self.p_exit = float(p_exit)
        self.factor = float(factor)
        self._states: List[np.ndarray] = [np.zeros(n, bool)]  # state BEFORE t

    def params(self) -> Dict:
        return {"p_enter": self.p_enter, "p_exit": self.p_exit,
                "factor": self.factor}

    def _state(self, t: int) -> np.ndarray:
        while len(self._states) <= t:
            k = len(self._states)
            u = _rng(self.seed, 3, k).random(self.n)
            prev = self._states[-1]
            nxt = np.where(prev, u >= self.p_exit, u < self.p_enter)
            self._states.append(nxt)
        return self._states[t]

    def multipliers(self, t: int) -> np.ndarray:
        return np.where(self._state(t), self.factor, 1.0)


# ---------------------------------------------------------------------------
# registry / factory — mirrors make_topology / make_aggregator
# ---------------------------------------------------------------------------
STRAGGLERS = {
    "none": NoStraggler,
    "fixed": FixedSlowStraggler,
    "lognormal": LognormalStraggler,
    "bursty": BurstyStraggler,
}

StragglerLike = Union[str, StragglerSampler, None]


def register_straggler(name: str, cls) -> None:
    STRAGGLERS[name.lower()] = cls


def make_straggler(spec: StragglerLike, n: int,
                   seed: int = 0) -> StragglerSampler:
    """Resolve a sampler from an instance, a registry name, or a CLI spec
    string ``"name[:pos1[:pos2...]]"`` with positional float parameters in
    declaration order, e.g. ``"fixed:0.25:4"`` (frac, factor),
    ``"lognormal:0.8"`` (sigma), ``"bursty:0.05:0.3:6"``.  None -> no
    stragglers (homogeneous fleet)."""
    if spec is None:
        return NoStraggler(n, seed)
    if isinstance(spec, StragglerSampler):
        return spec.rebind(n, seed)
    name, _, rest = str(spec).partition(":")
    name = name.lower()
    if name not in STRAGGLERS:
        raise KeyError(
            f"unknown straggler regime {name!r}; known: {sorted(STRAGGLERS)}")
    cls = STRAGGLERS[name]
    if not rest:
        return cls(n, seed)
    fields = [f for f in cls(2).params()]  # declaration order
    vals = [float(x) for x in rest.split(":")]
    if len(vals) > len(fields):
        raise ValueError(f"{name} takes at most {len(fields)} parameters "
                         f"({fields}), got {vals}")
    return cls(n, seed, **dict(zip(fields, vals)))
