"""Participation policies: who makes a sync's deadline, as a runtime mask.

A level-ℓ sync is a barrier within each level-(ℓ-1) subtree.  Under
heterogeneity the policy decides how long that barrier holds the door open:

* :class:`FullBarrier` (default) — everybody waits for the slowest member;
  bitwise the classic H-SGD semantics, just with the wait accounted.
* :class:`DeadlineElastic` — the subtree admits workers arriving within
  ``deadline_s(level)`` of an anchor arrival; later arrivals are dropped
  from this event only.  The anchor is a per-subtree quantile (default the
  MEDIAN, ``anchor="median"``), never an absolute clock, so at least one
  participant is always admitted and the weighted group mean is well
  defined.  ``anchor="min"`` (the fastest member) is sharper but fragile:
  a worker that skipped earlier barriers carries a clock LOW relative to
  the barrier-pushed fleet, and on return it would anchor the cutoff so
  low that the bulk of the subtree gets dropped — the median is robust to
  that (at least half the subtree is always admitted).

The policy's output is the repo's existing runtime-mask / partial-
participation contract (``admit`` -> (n,) bool): the clock hands the mask
to the engine, which aggregates over admitted workers only while dropped
workers keep their exact post-update params AND their unconsumed comms
residuals (they transmitted nothing, they received nothing — they were
still computing when the barrier closed).  Both executors honor it: see
``SimExecutor._build_round(..., masked=True)`` and the mesh backend's
mask-weighted collective lowering (``MeshExecutor`` docstring; DESIGN.md
has the full contract).
"""
from __future__ import annotations

import abc
from typing import Dict, Union

import numpy as np


class ParticipationPolicy(abc.ABC):
    """Per-subtree admission rule for one sync barrier."""

    #: True if this policy can drop workers (its drops route rounds through
    #: the executors' masked variants; full-barrier is pure accounting).
    elastic: bool = False

    @abc.abstractmethod
    def admit(self, level: int, arrivals: np.ndarray) -> np.ndarray:
        """arrivals: (k,) simulated arrival times of ONE aggregation
        subtree's members at a level-``level`` barrier.  Returns (k,) bool —
        the members admitted to this event."""


class FullBarrier(ParticipationPolicy):
    """Everyone syncs; the barrier waits for the slowest member."""

    def admit(self, level: int, arrivals: np.ndarray) -> np.ndarray:
        return np.ones(len(arrivals), bool)

    def __repr__(self):
        return "FullBarrier()"


class DeadlineElastic(ParticipationPolicy):
    """Admit workers arriving within ``deadline_s`` of the subtree's anchor
    arrival (default: the median); drop the rest from this event.

    deadline_s: one slack for every level, or a per-level dict
    ``{1: far_slack, 2: near_slack, ...}`` (missing levels fall back to
    ``default``, default inf = full barrier at that level).
    anchor: "median" (robust; at least half the subtree always admitted) or
    "min" (the fastest member; sharper, but see the module docstring).
    """

    elastic = True

    def __init__(self, deadline_s: Union[float, Dict[int, float]],
                 default: float = np.inf, anchor: str = "median"):
        if not isinstance(deadline_s, dict):
            deadline_s = {None: float(deadline_s)}
            default = deadline_s[None]
        self.deadline_s = {k: float(v) for k, v in deadline_s.items()}
        self.default = float(default)
        assert all(v >= 0.0 for v in self.deadline_s.values()) \
            and default >= 0.0, "deadlines are non-negative slacks"
        assert anchor in ("median", "min"), anchor
        self.anchor = anchor

    def deadline(self, level: int) -> float:
        return self.deadline_s.get(level, self.default)

    def admit(self, level: int, arrivals: np.ndarray) -> np.ndarray:
        ref = np.median(arrivals) if self.anchor == "median" \
            else arrivals.min()
        return arrivals <= ref + self.deadline(level)

    def __repr__(self):
        d = {k: v for k, v in self.deadline_s.items() if k is not None}
        return f"DeadlineElastic({d or self.default}, anchor={self.anchor!r})"


PolicyLike = Union[str, float, Dict[int, float], ParticipationPolicy, None]


def make_policy(spec: PolicyLike = None) -> ParticipationPolicy:
    """Resolve a policy: None/"full" -> FullBarrier; a number (or numeric
    string) -> DeadlineElastic with that slack at every level; a per-level
    CLI spec ``"L1:2.0,L2:0.5"`` -> DeadlineElastic({1: 2.0, 2: 0.5})."""
    if spec is None:
        return FullBarrier()
    if isinstance(spec, ParticipationPolicy):
        return spec
    if isinstance(spec, dict):
        return DeadlineElastic(spec)
    if isinstance(spec, (int, float)):
        return DeadlineElastic(float(spec))
    s = str(spec).strip()
    if s.lower() in ("full", "barrier", "full_barrier"):
        return FullBarrier()
    try:
        return DeadlineElastic(float(s))
    except ValueError:
        pass
    per_level: Dict[int, float] = {}
    for part in s.split(","):
        lvl, _, val = part.partition(":")
        lvl = lvl.strip().lstrip("Ll")
        if not lvl.isdigit() or not val:
            raise ValueError(
                f"bad deadline spec {spec!r}; want a slack in seconds "
                f"('2.0') or per-level 'L1:2.0,L2:0.5'")
        per_level[int(lvl)] = float(val)
    return DeadlineElastic(per_level)
