"""repro.runtime — simulated-time heterogeneity for H-SGD schedules.

Three parts (see the module docstrings for the design notes):

* :mod:`repro.runtime.clock` — ``RuntimeModel`` / ``SimClock``: event-driven
  per-worker clocks, per-level link models priced by the PR-3 wire
  accounting (codecs visibly buy time), exact monotonicity and
  elastic-never-slower invariants;
* :mod:`repro.runtime.stragglers` — per-worker compute-multiplier samplers
  (fixed slow set / lognormal / bursty Markov), pure in ``(seed, t)``;
* :mod:`repro.runtime.elastic` — participation policies (``FullBarrier`` /
  ``DeadlineElastic``) that convert missed deadlines into the engine's
  runtime-mask contract.

Enable on an engine with ``HSGD(..., runtime=RuntimeModel(...))``; the
default ``runtime=None`` is bitwise-identical to the runtime-free engine.
"""
from repro.runtime.clock import (LinkModel, RuntimeLike, RuntimeModel,
                                 SimClock, default_links, make_runtime)
from repro.runtime.elastic import (DeadlineElastic, FullBarrier,
                                   ParticipationPolicy, PolicyLike,
                                   make_policy)
from repro.runtime.stragglers import (STRAGGLERS, BurstyStraggler,
                                      FixedSlowStraggler, LognormalStraggler,
                                      NoStraggler, StragglerLike,
                                      StragglerSampler, make_straggler,
                                      register_straggler)

__all__ = [
    "RuntimeModel", "RuntimeLike", "make_runtime", "SimClock", "LinkModel",
    "default_links",
    "ParticipationPolicy", "FullBarrier", "DeadlineElastic", "PolicyLike",
    "make_policy",
    "StragglerSampler", "NoStraggler", "FixedSlowStraggler",
    "LognormalStraggler", "BurstyStraggler", "STRAGGLERS", "StragglerLike",
    "make_straggler", "register_straggler",
]
