"""Event-driven simulated time for H-SGD schedules.

The paper's whole argument is convergence per *wall-clock* cost — rare far
rounds win because near rounds are cheap — but the repo priced time as three
static constants (``planner.CommModel``).  This module simulates it:

* every worker carries its own clock, advanced per local step by
  ``compute_s`` x a :mod:`straggler <repro.runtime.stragglers>` multiplier;
* every :class:`~repro.core.topology.SyncEvent` is a barrier within each
  level-(ℓ-1) subtree, priced by per-level :class:`LinkModel`s —
  ``latency_s + payload_bytes / bandwidth`` per tree tier crossed, with
  ``payload_bytes`` the per-worker encoded payload from the PR-3 wire
  accounting (:class:`repro.comms.WireStats`), so compression codecs
  visibly buy simulated time;
* the bound :mod:`participation policy <repro.runtime.elastic>` decides who
  makes each barrier; drops become the engine's runtime-mask contract.

Everything is host-side numpy — zero device work, zero effect on the jitted
program (``HSGD(..., runtime=None)``, the default, is bitwise-identical to
no runtime at all; with a runtime and the default full-barrier policy the
*trajectory* is still bitwise-identical, only the accounting is added).

Two exact invariants, by construction (and property-tested):

1. **Monotone**: per-worker clocks never decrease (barriers only wait,
   drops keep the dropped worker's own later arrival).
2. **Elastic never slower**: with the same seed (so the same compute
   draws — samplers are pure in ``(seed, t)``), every worker's clock under
   ``DeadlineElastic`` is <= its clock under ``FullBarrier`` at every step:
   admitted workers wait for a subset (max over fewer arrivals), dropped
   workers keep an arrival that full-barrier would have raised past the
   global max anyway.  Induction gives the pointwise bound; the CI
   benchmark asserts it per straggler regime.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.runtime.elastic import (ParticipationPolicy, PolicyLike,
                                   make_policy)
from repro.runtime.stragglers import (StragglerLike, StragglerSampler,
                                      make_straggler)


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """One hierarchy tier's uplink: latency + bandwidth.  A sync payload
    crossing this tier costs ``latency_s + nbytes / bandwidth_Bps``."""
    latency_s: float
    bandwidth_Bps: float = np.inf   # bytes/second

    def __post_init__(self):
        assert self.latency_s >= 0.0 and self.bandwidth_Bps > 0.0, self

    def sync_s(self, nbytes: int) -> float:
        return self.latency_s + float(nbytes) / self.bandwidth_Bps


def default_links(num_levels: int) -> Tuple[LinkModel, ...]:
    """A plausible datacenter-ish ladder: the outermost tier (level 1, the
    cross-pod / WAN fabric) is slow, each deeper tier 10x faster — the
    near-vs-far asymmetry the paper's Table E.1 measures."""
    return tuple(LinkModel(latency_s=0.1 * 10.0 ** -(l - 1),
                           bandwidth_Bps=1e8 * 10.0 ** (l - 1))
                 for l in range(1, num_levels + 1))


@dataclasses.dataclass(frozen=True)
class RuntimeModel:
    """The engine-facing bundle: ``HSGD(..., runtime=RuntimeModel(...))``.

    compute_s:  nominal seconds per local update (scaled per worker/step by
                the straggler sampler).
    links:      one :class:`LinkModel` per hierarchy level, level 1 first
                (None -> :func:`default_links` for the bound topology).
    straggler:  sampler instance / registry spec ("fixed:0.25:4" ...) /
                None (homogeneous).
    policy:     participation policy / deadline spec ("2.0", "L1:2.0,L2:0.5",
                a number) / None (full barrier).
    seed:       sampler seed (pure counter-based draws — see stragglers.py).
    """
    compute_s: float = 1.0
    links: Optional[Tuple[LinkModel, ...]] = None
    straggler: StragglerLike = None
    policy: PolicyLike = None
    seed: int = 0

    def __post_init__(self):
        assert self.compute_s > 0.0, self

    @property
    def elastic(self) -> bool:
        return make_policy(self.policy).elastic

    def clock(self, topology, payload_bytes: int,
              recorder=None) -> "SimClock":
        """Bind to a topology + per-worker payload size -> a fresh clock.
        ``recorder`` (a :class:`repro.obs.TraceRecorder`) gets per-worker
        compute/wait spans and per-subtree sync spans in simulated time."""
        return SimClock(self, topology, payload_bytes, recorder)


RuntimeLike = Union[RuntimeModel, None]


def make_runtime(spec: RuntimeLike = None, **kwargs) -> Optional[RuntimeModel]:
    """Resolve the ``HSGD(..., runtime=...)`` argument (None = off, the
    bitwise-identical default)."""
    if spec is None and not kwargs:
        return None
    if isinstance(spec, RuntimeModel):
        assert not kwargs, "kwargs only apply when constructing from scratch"
        return spec
    assert spec is None, f"runtime must be a RuntimeModel or None, got {spec!r}"
    return RuntimeModel(**kwargs)


class SimClock:
    """Per-worker simulated clocks over one topology's schedule.

    The engine drives it with ``advance(t)`` (one local update everywhere)
    and ``sync(event)`` (one barrier; returns the (n,) participation mask,
    or None when nobody was dropped).  ``time_s`` is the makespan (max over
    worker clocks); ``comm_s`` attributes barrier link time per level
    (parallel subtrees overlap, so each event counts its link cost once).
    """

    def __init__(self, model: RuntimeModel, topology, payload_bytes: int,
                 recorder=None):
        self.model = model
        self.topology = topology
        self.payload_bytes = int(payload_bytes)
        self.recorder = recorder  # optional repro.obs.TraceRecorder
        self.n = topology.n
        self.num_levels = len(topology.periods)
        links = model.links if model.links is not None \
            else default_links(self.num_levels)
        assert len(links) == self.num_levels, \
            f"need one LinkModel per hierarchy level ({self.num_levels}), " \
            f"got {len(links)}"
        self.links = tuple(links)
        self.sampler: StragglerSampler = make_straggler(
            model.straggler, self.n, model.seed)
        self.policy: ParticipationPolicy = make_policy(model.policy)
        # level-ℓ barrier groups = the level-(ℓ-1) subtrees
        groupings = topology.level_groupings()
        self._subtrees: Dict[int, List[np.ndarray]] = {
            1: [np.arange(self.n)]}
        for lvl, g in groupings.items():
            self._subtrees[lvl + 1] = [g.members(i) for i in range(g.N)]
        self.clocks = np.zeros(self.n)
        self.compute_s = np.zeros(self.n)   # per-worker compute total
        self.wait_s = np.zeros(self.n)      # per-worker barrier-wait total
        self.comm_s = {l: 0.0 for l in range(1, self.num_levels + 1)}
        self.n_dropped = {l: 0 for l in range(1, self.num_levels + 1)}
        self.n_synced = {l: 0 for l in range(1, self.num_levels + 1)}
        # per level: who made the most recent event, and when its (slowest
        # participating) barrier completed — the "published model" telemetry:
        # right after a level-1 sync, the admitted workers all hold the
        # global aggregate, available at last_sync_time[1] regardless of
        # where the dropped stragglers' clocks are
        self.last_admitted: Dict[int, np.ndarray] = {}
        self.last_sync_time: Dict[int, float] = {}

    # -- time queries --------------------------------------------------------
    @property
    def time_s(self) -> float:
        """Simulated makespan: the slowest worker's clock."""
        return float(self.clocks.max())

    def event_cost_s(self, level: int) -> float:
        """Static link time of one level-``level`` sync: the payload crosses
        every tree tier ``level..M`` on the way up (the PR-3 wire model's
        cost structure, priced per tier)."""
        return sum(self.links[j - 1].sync_s(self.payload_bytes)
                   for j in range(level, self.num_levels + 1))

    # -- the two engine hooks ------------------------------------------------
    def advance(self, t: int) -> None:
        """One local update of step ``t`` on every worker."""
        dt = self.model.compute_s * self.sampler.multipliers(t)
        if self.recorder is not None:
            for w in range(self.n):
                self.recorder.compute_span(w, float(self.clocks[w]),
                                           float(dt[w]))
        self.clocks += dt
        self.compute_s += dt

    def sync(self, event) -> Optional[np.ndarray]:
        """One barrier for ``event``.  Returns the (n,) bool participation
        mask when the policy dropped someone, else None (everyone synced —
        the engine runs its unmasked fast path)."""
        part = self.topology.participants(event)
        subtrees = self._subtrees.get(event.level)
        if subtrees is None:
            raise ValueError(
                f"no barrier structure for level {event.level} on "
                f"{type(self.topology).__name__} (levels: "
                f"{sorted(self._subtrees)})")
        cost = self.event_cost_s(event.level)
        mask = np.ones(self.n, bool)
        admitted_all = np.zeros(self.n, bool)
        t_done = 0.0
        dropped_any = False
        for members in subtrees:
            if part is not None:
                members = members[part[members]]
                if len(members) == 0:
                    continue   # non-participating group: no barrier, no cost
            arrivals = self.clocks[members]
            made = self.policy.admit(event.level, arrivals)
            assert made.any(), \
                "policy admitted nobody (DeadlineElastic anchors on a " \
                "subtree arrival quantile, so this cannot happen there)"
            if not made.all():
                dropped_any = True
                mask[members[~made]] = False
            admitted = members[made]
            t_sync = arrivals[made].max() + cost
            if self.recorder is not None:
                barrier_open = float(arrivals[made].max())
                self.recorder.sync_span(
                    event.level, barrier_open, cost,
                    payload_bytes=self.payload_bytes,
                    dropped=int((~made).sum()))
                for w, arr in zip(admitted, arrivals[made]):
                    wait = barrier_open - float(arr)
                    if wait > 0.0:
                        self.recorder.wait_span(int(w), event.level,
                                                float(arr), wait)
            self.wait_s[admitted] += t_sync - cost - self.clocks[admitted]
            self.clocks[admitted] = t_sync
            admitted_all[admitted] = True
            t_done = max(t_done, t_sync)
            self.n_synced[event.level] += int(made.sum())
            self.n_dropped[event.level] += int((~made).sum())
        self.comm_s[event.level] += cost
        self.last_admitted[event.level] = admitted_all
        self.last_sync_time[event.level] = t_done
        return mask if dropped_any else None

    # -- reporting -----------------------------------------------------------
    def level_seconds(self) -> Dict[str, float]:
        """Cumulative per-level barrier link time (each event once — the
        subtrees of one event run in parallel) — the history's
        ``sim_sync_s`` breakdown."""
        return {f"L{l}": round(s, 9) for l, s in self.comm_s.items()}

    def breakdown(self) -> Dict:
        """JSON-able accounting of where the simulated time went."""
        return {
            "time_s": round(self.time_s, 6),
            "compute_s": {"max": round(float(self.compute_s.max()), 6),
                          "mean": round(float(self.compute_s.mean()), 6)},
            "wait_s": {"max": round(float(self.wait_s.max()), 6),
                       "mean": round(float(self.wait_s.mean()), 6)},
            "sync_s": self.level_seconds(),
            "synced": dict(self.n_synced),
            "dropped": dict(self.n_dropped),
            "payload_bytes": self.payload_bytes,
            "event_cost_s": {f"L{l}": round(self.event_cost_s(l), 9)
                             for l in range(1, self.num_levels + 1)},
        }
