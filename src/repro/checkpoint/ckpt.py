"""Pytree checkpointing: msgpack container + raw numpy buffers.

Atomic (write to tmp + rename), step-indexed, restores onto a pytree template.
bfloat16 leaves round-trip via a uint16 view (no numpy wire format).
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_BF16 = np.dtype(jnp.bfloat16)


def _to_wire(leaf) -> np.ndarray:
    a = np.asarray(leaf)
    return a.view(np.uint16) if a.dtype == _BF16 else a


def save(path: str, step: int, tree: Any) -> str:
    os.makedirs(path, exist_ok=True)
    leaves = jax.tree.leaves(tree)
    payload = []
    for l in leaves:
        a = _to_wire(l)
        payload.append({
            "dtype": str(np.dtype(jnp.result_type(l))),
            "wire": str(a.dtype),
            "shape": list(a.shape),
            "data": np.ascontiguousarray(a).tobytes(),
        })
    blob = msgpack.packb({"step": step, "payload": payload}, use_bin_type=True)
    fname = os.path.join(path, f"ckpt_{step:08d}.msgpack")
    fd, tmp = tempfile.mkstemp(dir=path)
    with os.fdopen(fd, "wb") as f:
        f.write(blob)
    os.replace(tmp, fname)
    return fname


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(f[5:13]) for f in os.listdir(path)
             if f.startswith("ckpt_") and f.endswith(".msgpack")]
    return max(steps) if steps else None


def restore(path: str, template: Any, step: Optional[int] = None):
    """Returns (step, tree shaped/dtyped like template)."""
    if step is None:
        step = latest_step(path)
        assert step is not None, f"no checkpoints under {path}"
    with open(os.path.join(path, f"ckpt_{step:08d}.msgpack"), "rb") as f:
        blob = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = jax.tree.flatten(template)
    stored = blob["payload"]
    assert len(stored) == len(leaves), "checkpoint/template structure mismatch"
    out = []
    for tmpl, rec in zip(leaves, stored):
        arr = np.frombuffer(rec["data"],
                            dtype=np.dtype(rec["wire"])).reshape(rec["shape"])
        want = np.dtype(rec["dtype"])
        if want == _BF16:
            arr = arr.view(_BF16)
        arr = jnp.asarray(arr, dtype=want)
        assert arr.shape == tuple(np.shape(tmpl)), (arr.shape, np.shape(tmpl))
        out.append(arr)
    return blob["step"], jax.tree.unflatten(treedef, out)
