"""Batched serving engine: prefill once, decode step-by-step.

Caches come from the model (full KV, sliding-window ring, SSM state, RG-LRU
state — see repro.models.transformer.block_cache_init).  All requests in a
batch decode in lockstep (static shapes; production would add continuous
batching on top — out of scope for a training-technique paper, noted in
DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, gen_len)
    logprobs: np.ndarray        # (B, gen_len)
    steps: int


class DecodeEngine:
    def __init__(self, model, params, *, temperature: float = 0.0):
        self.model = model
        self.params = params
        self.temperature = temperature
        self._prefill = jax.jit(model.prefill, static_argnames=("max_len",))
        self._step = jax.jit(model.decode_step)

    def _sample(self, key, logits):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.temperature, axis=-1
                                      ).astype(jnp.int32)

    def generate(self, prompt: jax.Array, gen_len: int, *,
                 key: Optional[jax.Array] = None,
                 enc_inputs: Optional[jax.Array] = None) -> GenerationResult:
        """prompt: (B, S) int32. Greedy (or temperature) continuation."""
        key = key if key is not None else jax.random.PRNGKey(0)
        b, s = prompt.shape
        max_len = s + gen_len
        kw = {"enc_inputs": enc_inputs} if enc_inputs is not None else {}
        logits, cache = self._prefill(self.params, prompt, max_len=max_len, **kw)
        toks, lps = [], []
        tok = self._sample(key, logits)
        for t in range(gen_len):
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            lps.append(np.asarray(jnp.take_along_axis(
                logp, tok[:, None], axis=-1))[:, 0])
            toks.append(np.asarray(tok))
            if t + 1 < gen_len:
                key, sub = jax.random.split(key)
                logits, cache = self._step(self.params, cache, tok)
                tok = self._sample(sub, logits)
        return GenerationResult(np.stack(toks, 1), np.stack(lps, 1), gen_len)

    def score_continuation(self, prompt: jax.Array,
                           continuation: jax.Array,
                           enc_inputs: Optional[jax.Array] = None) -> np.ndarray:
        """Sum logprob of a given continuation (evaluation utility)."""
        b, s = prompt.shape
        g = continuation.shape[1]
        kw = {"enc_inputs": enc_inputs} if enc_inputs is not None else {}
        logits, cache = self._prefill(self.params, prompt,
                                      max_len=s + g, **kw)
        total = np.zeros(b, np.float64)
        tok = continuation[:, 0]
        for t in range(g):
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            total += np.asarray(jnp.take_along_axis(
                logp, tok[:, None], axis=-1))[:, 0]
            if t + 1 < g:
                logits, cache = self._step(self.params, cache, tok)
                tok = continuation[:, t + 1]
        return total
