"""End-to-end driver example: H-SGD-train a reduced qwen2-family LM on the
synthetic token stream, with checkpointing, divergence telemetry, and the
simulated-time heterogeneity engine.

    PYTHONPATH=src python examples/train_hsgd.py

(The full-size run is the same command without --reduced on a TPU fleet.)

The --runtime/--straggler/--deadline flags price the schedule in simulated
seconds: every worker's clock advances per local step (here with
heavy-tailed lognormal jitter), sync events barrier within their subtree
and cost latency + payload-bytes/bandwidth per tier crossed (the int8 comms
codec shrinks the payload, visibly buying time), and workers that miss a
sync's deadline are dropped from that event only — keeping their exact
params and comms residuals.  Telemetry records gain sim_time_s /
sim_sync_s, and the run ends with a runtime breakdown plus planner
constants fitted from the trace (CommModel.fit_from_trace).
"""
from repro.launch.train import main

if __name__ == "__main__":
    main([
        "--arch", "qwen2-0.5b", "--reduced",
        "--workers", "8", "--groups", "2", "--G", "8", "--I", "2",
        "--steps", "120", "--batch", "4", "--seq", "64",
        "--lr", "3e-3", "--optimizer", "momentum",
        "--comms", "int8",
        "--runtime", "0.004,0.005:1e9,0.0003:1e10",
        "--straggler", "lognormal:0.8",
        "--deadline", "0.004",
        "--log-every", "10", "--divergence-every", "40",
        "--ckpt-dir", "/tmp/hsgd_ckpt", "--ckpt-every", "60",
        "--out", "/tmp/hsgd_history.json",
    ])
