"""End-to-end driver example: H-SGD-train a reduced qwen2-family LM on the
synthetic token stream, with checkpointing and divergence telemetry.

    PYTHONPATH=src python examples/train_hsgd.py

(The full-size run is the same command without --reduced on a TPU fleet.)
"""
from repro.launch.train import main

if __name__ == "__main__":
    main([
        "--arch", "qwen2-0.5b", "--reduced",
        "--workers", "8", "--groups", "2", "--G", "8", "--I", "2",
        "--steps", "120", "--batch", "4", "--seq", "64",
        "--lr", "3e-3", "--optimizer", "momentum",
        "--log-every", "10", "--divergence-every", "40",
        "--ckpt-dir", "/tmp/hsgd_ckpt", "--ckpt-every", "60",
        "--out", "/tmp/hsgd_history.json",
    ])
