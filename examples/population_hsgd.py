"""Population-scale H-SGD: one million virtual clients, eight active slots.

    PYTHONPATH=src python examples/population_hsgd.py

The engine state only ever materializes the k = topology.n active slots; the
1,000,000-client population exists as a sampling *law* (pure in
``(seed, round)``, repro.population) plus per-client shard *specs* (pure in
``(seed, client_id, step)``, repro.data.PopulationShards).  Each sampling
round draws 8 clients hierarchically — 2 of 1000 cells, then 4 of 1000
clients per cell, the paper's Theorem-2 random regrouping drawn from a
population — runs one global period of the unchanged H-SGD engine, and
folds the result back into the server model with dataset-size weights.
"""
import jax

from repro.core import EngineConfig, HSGD, make_topology
from repro.data import PopulationShards
from repro.models import SimpleConfig, SimpleModel
from repro.optim import sgd
from repro.population import Population

# the task: a 10-class Gaussian mixture, sharded label-skewed over 10^6
# virtual clients (2 labels each, lognormal dataset sizes) — nothing of
# population size is ever materialized
shards = PopulationShards(population=1_000_000, num_classes=10, dim=24,
                          seed=0)
model = SimpleModel(SimpleConfig(kind="mlp", input_dim=24, hidden=32,
                                 num_classes=10))

# topology over the 8 ACTIVE slots (2 cells x 4 clients); the population
# declares 1000x1000 cells behind them, sampled 2-of-1000 then 4-of-1000
topology = make_topology("two_level", n=8, N=2, G=8, I=2)
engine = HSGD(model.loss, sgd(0.08), topology, EngineConfig(
    population=Population(cells=(1000, 1000), seed=7, weighting="size")))

server = engine.init_server(jax.random.PRNGKey(0), model.init)
server, history = engine.run_sampled(
    server, shards.batch_fn(batch_size=10), rounds=12,
    sizes=shards.size_fn())

for rec in history:
    p = rec["participation"]
    print(f"round {rec['round']:2d}  step {rec['t']:3d}  "
          f"train loss {rec['ce']:.4f}  "
          f"clients seen {p['unique']:3d}/{p['population']}")
