"""Quickstart: two-level H-SGD on a non-IID problem in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import EngineConfig, HSGD, make_topology
from repro.data import FederatedDataset, label_shard_partition, make_classification
from repro.models import SimpleConfig, SimpleModel
from repro.optim import sgd

# 8 workers, each holding ONE class of a 8-class problem (maximally non-IID)
x, y = make_classification(seed=0, num_classes=8, dim=24, per_class=80)
ds = FederatedDataset(x, y, label_shard_partition(y, [[j] for j in range(8)],
                                                  n_workers=8))
ds.require_workers(8)  # fail here, not as a shape error mid-round

model = SimpleModel(SimpleConfig(kind="mlp", input_dim=24, hidden=32,
                                 num_classes=8))

# H-SGD: 2 groups x 4 workers; local aggregation every I=4 steps (cheap,
# within a group), global aggregation every G=16 steps (expensive).
# EngineConfig() is where every pluggable subsystem goes (executor, comms,
# runtime, metrics, population) — the defaults are the plain engine.
topology = make_topology("two_level", n=8, N=2, G=16, I=4)
engine = HSGD(model.loss, sgd(0.08), topology, EngineConfig())
state = engine.init(jax.random.PRNGKey(0), model.init)

gb = jax.tree.map(jnp.asarray, ds.global_batch())


def evaluate(state, t):
    wbar = engine.mean_params(state)  # observable at global boundaries
    return {"loss": float(model.loss(wbar, gb)[0]),
            "acc": float(model.accuracy(wbar, gb))}


# the schedule-compiled executor: each pure-local block between sync events
# runs as ONE jitted lax.scan call instead of per-step dispatch
state, history = engine.run_rounds(
    state, lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 10)), T=96,
    eval_every=16, eval_fn=evaluate)

for rec in history:
    if "acc" in rec:
        event = engine.topology.event_at(rec["t"] - 1)
        print(f"step {rec['t']:3d}  sync=level-{event.level}  "
              f"global loss {rec['loss']:.4f}  acc {rec['acc']:.3f}")
