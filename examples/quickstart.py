"""Quickstart: two-level H-SGD on a non-IID problem in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import HSGD, UniformTopology, two_level
from repro.data import FederatedDataset, label_shard_partition, make_classification
from repro.models import SimpleConfig, SimpleModel
from repro.optim import sgd

# 8 workers, each holding ONE class of a 8-class problem (maximally non-IID)
x, y = make_classification(seed=0, num_classes=8, dim=24, per_class=80)
ds = FederatedDataset(x, y, label_shard_partition(y, [[j] for j in range(8)]))

model = SimpleModel(SimpleConfig(kind="mlp", input_dim=24, hidden=32,
                                 num_classes=8))

# H-SGD: 2 groups x 4 workers; local aggregation every I=4 steps (cheap,
# within a group), global aggregation every G=16 steps (expensive)
engine = HSGD(model.loss, sgd(0.08), UniformTopology(two_level(8, 2, G=16, I=4)))
state = engine.init(jax.random.PRNGKey(0), model.init)

gb = jax.tree.map(jnp.asarray, ds.global_batch())
for t in range(96):
    state, metrics = engine.step(state, jax.tree.map(jnp.asarray, ds.batch(t, 10)))
    if (t + 1) % 16 == 0:  # w-bar is observable at global boundaries
        wbar = engine.mean_params(state)
        print(f"step {t+1:3d}  sync=level-{engine.topology.step_kind(t)[1]}  "
              f"global loss {float(model.loss(wbar, gb)[0]):.4f}  "
              f"acc {float(model.accuracy(wbar, gb)):.3f}")
