"""Multi-level H-SGD (paper §5, Algorithm D.1): a 3-level hierarchy
(2 pods x 2 racks x 2 hosts) with nested periods P=(16, 4, 2), reproducing
the Fig. E.8 behaviour: mid-level aggregation between the extremes.

    PYTHONPATH=src python examples/multilevel_hsgd.py
"""
import jax
import jax.numpy as jnp

from repro.core import HSGD, HierarchySpec, local_sgd, make_topology
from repro.data import FederatedDataset, label_shard_partition, make_classification
from repro.models import SimpleConfig, SimpleModel
from repro.optim import sgd

x, y = make_classification(seed=3, num_classes=8, dim=24, per_class=80)
ds = FederatedDataset(x, y, label_shard_partition(y, [[j] for j in range(8)]))
model = SimpleModel(SimpleConfig(kind="mlp", input_dim=24, hidden=32,
                                 num_classes=8))
gb = jax.tree.map(jnp.asarray, ds.global_batch())


def run(name, spec, T=96):
    eng = HSGD(model.loss, sgd(0.08), make_topology("uniform", spec=spec))
    st = eng.init(jax.random.PRNGKey(0), model.init)
    st, _ = eng.run_rounds(
        st, lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 10)), T)
    wbar = eng.mean_params(st)
    print(f"{name:28s} final global loss "
          f"{float(model.loss(wbar, gb)[0]):.4f}")


run("local SGD P=2 (best)", local_sgd(8, 2))
run("3-level P=(16,4,2)", HierarchySpec((2, 2, 2), (16, 4, 2)))
run("2-level G=16, I=2", HierarchySpec((2, 4), (16, 2)))
run("local SGD P=16 (worst)", local_sgd(8, 16))
