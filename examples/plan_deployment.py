"""Deployment planning: choose (N, G, I) from Theorem 2 + a measured
communication-cost model (the paper's conclusion, made executable).

    PYTHONPATH=src python examples/plan_deployment.py
"""
from repro.core import CommModel, best_under_budget, enumerate_plans, pareto_front

# paper Table E.1, CNN: near round 0.29 ms, far round 4.53 ms, 4 ms/iter
comm = CommModel(compute_s=0.004, local_round_s=0.00029,
                 global_round_s=0.00453)

plans = enumerate_plans(
    n=64, T=20_000, L=1.0, sigma2=1.0, eps_tilde2=1.0, f0_minus_fstar=2.0,
    comm=comm)

print(f"{len(plans)} candidate (N, G, I) plans; Pareto front "
      "(wall-clock vs Theorem-2 bound):")
print(f"{'N':>3} {'G':>4} {'I':>3} {'bound':>10} {'wall(s)':>9}")
for p in pareto_front(plans)[:12]:
    print(f"{p.N:>3} {p.G:>4} {p.I:>3} {p.bound:>10.4f} {p.wall_s:>9.1f}")

budget = min(p.wall_s for p in plans) * 1.10
best = best_under_budget(plans, budget)
print(f"\nbest plan within {budget:.1f}s wall-clock: "
      f"N={best.N}, G={best.G}, I={best.I} "
      f"(bound {best.bound:.4f}, wall {best.wall_s:.1f}s) — note I < G: "
      "the planner rediscovers the paper's 'frequent local, rare global'.")
