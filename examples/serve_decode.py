"""Serving example: batched prefill + decode against every cache type
(full KV, sliding-window ring, SSM state, RG-LRU state, enc-dec cross-KV).

    PYTHONPATH=src python examples/serve_decode.py
"""
import jax

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.models.frontends import synth_audio_frames
from repro.serving import DecodeEngine

for arch in ("gemma3-12b", "mamba2-130m", "recurrentgemma-2b",
             "seamless-m4t-large-v2"):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    engine = DecodeEngine(model, params, temperature=0.0)
    prompt = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_inputs"] = synth_audio_frames(key, cfg, 2, 4)
    res = engine.generate(prompt, 8, **kw)
    print(f"{arch:24s} [{cfg.family}] tokens: {res.tokens[0].tolist()}")
