"""Roofline machinery tests: HLO cost model vs XLA on loop-free modules,
trip-count awareness, collective parsing, hardware-term arithmetic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import HW, RooflineReport
from repro.roofline.hlo_cost import ModuleCost, analyze_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_flops_match_xla_on_unrolled(rng):
    d = 64
    W = jax.random.normal(rng, (8, d, d))
    x = jax.random.normal(rng, (4, d))

    def unrolled(x, W):
        for i in range(8):
            x = jnp.tanh(x @ W[i])
        return x.sum()

    comp = _compile(unrolled, x, W)
    xla = comp.cost_analysis()
    if isinstance(xla, list):
        xla = xla[0]
    mine = analyze_hlo(comp.as_text())
    assert abs(mine.flops - float(xla["flops"])) / float(xla["flops"]) < 0.02
    assert abs(mine.bytes - float(xla["bytes accessed"])) / \
        float(xla["bytes accessed"]) < 0.10


def test_while_trip_count_multiplies(rng):
    d = 32
    W = jax.random.normal(rng, (16, d, d))
    x = jax.random.normal(rng, (4, d))

    def scanned(x, W):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, W)
        return y.sum()

    def unrolled(x, W):
        for i in range(16):
            x = jnp.tanh(x @ W[i])
        return x.sum()

    f_scan = analyze_hlo(_compile(scanned, x, W).as_text()).flops
    f_unroll = analyze_hlo(_compile(unrolled, x, W).as_text()).flops
    assert abs(f_scan - f_unroll) / f_unroll < 0.02
    # and the analytic count
    analytic = 16 * 2 * 4 * d * d
    assert abs(f_scan - analytic) / analytic < 0.05


def test_dot_flops_exact(rng):
    a = jax.random.normal(rng, (32, 48))
    b = jax.random.normal(rng, (48, 16))
    comp = _compile(lambda a, b: a @ b, a, b)
    mine = analyze_hlo(comp.as_text())
    assert mine.flops == pytest.approx(2 * 32 * 48 * 16, rel=0.01)


def test_nested_scan_multiplies(rng):
    d = 16
    W = jax.random.normal(rng, (4, d, d))

    def nested(x, W):
        def outer(x, w):
            def inner(x, _):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(inner, x, None, length=5)
            return x, None
        y, _ = jax.lax.scan(outer, x, W)
        return y.sum()

    x = jax.random.normal(rng, (2, d))
    mine = analyze_hlo(_compile(nested, x, W).as_text())
    analytic = 4 * 5 * 2 * 2 * d * d
    assert abs(mine.flops - analytic) / analytic < 0.10


def test_roofline_terms_arithmetic():
    r = RooflineReport(name="x", flops_per_chip=197e12, bytes_per_chip=819e9,
                       coll_intra=50e9, coll_cross=25e9,
                       coll_by_kind={}, peak_memory_bytes=None, hw=HW())
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(2.0)  # 1s ICI + 1s DCI
    assert r.dominant == "collective"


def test_iota_replica_group_cross_pod_detection():
    from repro.roofline.hlo_cost import Instr, ModuleCost
    mc = ModuleCost("", pod_size=256)
    # groups spanning both pods of a (2,16,16) mesh
    ins = Instr("x", [("f32", (4,))], "all-reduce", ["y"],
                ", replica_groups=[16,32]<=[2,16,16]T(1,0,2), "
                "use_global_device_ids=true")
    nbytes, cross = mc._collective(ins)
    assert nbytes == 16
    assert cross is True
    ins2 = Instr("x", [("f32", (4,))], "all-reduce", ["y"],
                 ", replica_groups=[32,16]<=[2,16,16]T(2,0,1), "
                 "use_global_device_ids=true")
    _, cross2 = mc._collective(ins2)
    assert cross2 is False  # groups within one pod's model axis
