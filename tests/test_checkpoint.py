"""Checkpoint roundtrip tests (incl. bfloat16 wire format, latest-step)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save


def test_roundtrip_mixed_dtypes(tmp_path, rng):
    tree = {
        "a": jax.random.normal(rng, (4, 5)),
        "b": {"c": jnp.arange(7, dtype=jnp.int32),
              "d": jax.random.normal(rng, (3,)).astype(jnp.bfloat16)},
        "scalar": jnp.asarray(2, jnp.int32),
    }
    save(str(tmp_path), 12, tree)
    step, back = restore(str(tmp_path), tree)
    assert step == 12
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step(tmp_path):
    tree = {"x": jnp.zeros(2)}
    assert latest_step(str(tmp_path)) is None
    save(str(tmp_path), 3, tree)
    save(str(tmp_path), 10, tree)
    assert latest_step(str(tmp_path)) == 10
    step, _ = restore(str(tmp_path), tree)
    assert step == 10


def test_structure_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, {"x": jnp.zeros(2)})
    with pytest.raises(AssertionError):
        restore(str(tmp_path), {"x": jnp.zeros(2), "y": jnp.zeros(1)})


def test_train_state_roundtrip(tmp_path, rng):
    """Full HSGD state roundtrips (resume support)."""
    from repro.core import HSGD, UniformTopology, two_level
    from repro.models import SimpleConfig, SimpleModel
    from repro.optim import momentum
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=8, hidden=8,
                                     num_classes=4))
    eng = HSGD(model.loss, momentum(0.1), UniformTopology(two_level(4, 2, 4, 2)))
    st = eng.init(rng, model.init)
    tree = {"params": st.params, "opt": st.opt_state, "step": st.step}
    save(str(tmp_path), 0, tree)
    _, back = restore(str(tmp_path), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
