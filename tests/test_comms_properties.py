"""Property-based tests (hypothesis) for every registered Compressor:
round-trip error contracts and idempotence — re-encoding a decoded payload
must be a fixed point (up to f32 rounding), which is what makes a codec a
well-defined wire format rather than a one-shot perturbation."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.comms import (IdentityCompressor, Int8Compressor, SignCompressor,
                         TopKCompressor)
from repro.comms.codecs import COMPRESSORS  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

# one representative instance per registered codec CLASS (registry names
# alias: identity/none, int8/q8, sign/1bit); small blocks keep interpret
# mode fast while exercising the padded-tail path
INSTANCES = [IdentityCompressor(), Int8Compressor(block=32),
             SignCompressor(block=32), TopKCompressor(rate=0.25)]


def test_every_registered_codec_is_covered():
    assert {type(c) for c in INSTANCES} == set(COMPRESSORS.values())


def _payload(rows, length, seed, scale):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(rows, length)) * scale, jnp.float32)


LENGTHS = st.sampled_from([1, 7, 31, 32, 33, 64, 100, 171, 256])


@pytest.mark.parametrize("codec", INSTANCES, ids=lambda c: c.name)
@given(rows=st.integers(1, 4), length=LENGTHS,
       seed=st.integers(0, 10**6),
       scale=st.floats(1e-3, 1e3))
def test_roundtrip_and_idempotence(codec, rows, length, seed, scale):
    x = _payload(rows, length, seed, scale)
    once, res = codec.roundtrip(x)
    assert once.shape == x.shape and res is None  # no residual threaded
    twice, _ = codec.roundtrip(once)
    # idempotence: the decoded payload is a fixed point of the codec
    tol = 1e-5 * scale + 1e-6
    np.testing.assert_allclose(np.asarray(twice), np.asarray(once),
                               atol=tol, rtol=1e-5)


@given(rows=st.integers(1, 3), length=LENGTHS,
       seed=st.integers(0, 10**6))
def test_identity_is_exact(rows, length, seed):
    x = _payload(rows, length, seed, 1.0)
    once, _ = IdentityCompressor().roundtrip(x)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(x))


@given(rows=st.integers(1, 3), length=LENGTHS,
       seed=st.integers(0, 10**6))
def test_int8_blockwise_error_bound(rows, length, seed):
    """|x - rt| <= half a quantization step of the block max."""
    blk = 32
    x = _payload(rows, length, seed, 1.0)
    rt, _ = Int8Compressor(block=blk).roundtrip(x)
    xn, rn = np.asarray(x), np.asarray(rt)
    nb = -(-length // blk)
    pad = np.zeros((rows, nb * blk - length), np.float32)
    xb = np.concatenate([xn, pad], 1).reshape(rows, nb, blk)
    rb = np.concatenate([rn, pad], 1).reshape(rows, nb, blk)
    bound = np.abs(xb).max(-1, keepdims=True) / 127.0 * 0.5 + 1e-6
    assert (np.abs(xb - rb) <= bound).all()


@given(rows=st.integers(1, 3), length=LENGTHS,
       seed=st.integers(0, 10**6))
def test_sign_preserves_signs_and_scale(rows, length, seed):
    blk = 32
    x = _payload(rows, length, seed, 1.0)
    rt, _ = SignCompressor(block=blk).roundtrip(x)
    xn, rn = np.asarray(x), np.asarray(rt)
    assert (np.sign(rn) == np.where(xn >= 0, 1.0, -1.0)).all()
    # block magnitudes are mean |x| over REAL entries (padding excluded)
    tail = xn[:, (length // blk) * blk:]
    if tail.size:
        np.testing.assert_allclose(np.abs(rn[:, -1]),
                                   np.abs(tail).mean(1), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([2, 8, 64, 256]), length=LENGTHS,
       seed=st.integers(0, 10**6),
       exps=st.lists(st.integers(-20, 20), min_size=1, max_size=8))
def test_int8_wire_reduce_matches_f32_oracle(n, length, seed, exps):
    """The compressed allreduce: int8 payloads psum in the wire dtype with
    an int32-widened accumulator.  Because |Σ q| <= 127·n_workers < 2^24,
    the widened integer sum is EXACTLY representable in f32, so the wire
    path must match a pure-f32 oracle bitwise for any worker count up to
    256 and any adversarial per-block magnitude (10^k, k in [-20, 20])."""
    from repro.comms.reduce import SimWireOps
    from repro.kernels.ref import int8_scale_quant_ref

    blk = 32
    nb = -(-length // blk)
    rng = np.random.default_rng(seed)
    mags = np.array([10.0 ** exps[j % len(exps)] for j in range(nb)],
                    np.float32)
    xn = rng.normal(size=(n, length)).astype(np.float32)
    xn *= np.repeat(mags, blk)[:length]
    x = jnp.asarray(xn)

    out, res = Int8Compressor(block=blk).reduce(x, SimWireOps((n,), 1))
    assert res is None and out.shape == x.shape

    # f32 oracle: shared group-amax scale, jnp quantizer oracle, f32 sum of
    # the small integers (exact), decode, participant mean
    pad = np.zeros((n, nb * blk - length), np.float32)
    xb = np.concatenate([xn, pad], 1).reshape(n, nb, blk)
    scale = (np.abs(xb).max(-1).max(0) / 127.0).astype(np.float32)  # (nb,)
    q = np.asarray(int8_scale_quant_ref(
        x, jnp.asarray(np.broadcast_to(scale, (n, nb))), blk))
    assert q.dtype == np.int8
    qsum = q.astype(np.float32).sum(0)                  # exact integers
    qpad = np.concatenate([qsum, np.zeros(nb * blk - length, np.float32)])
    dense = (qpad.reshape(nb, blk) * scale[:, None]).reshape(-1)[:length]
    dense = dense / np.float32(n)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.broadcast_to(dense, (n, length)))
    # and the reduced mean is within half a quantization step of the true
    # group mean (no clipping: the shared scale covers every worker)
    bound = 0.5 * np.repeat(scale, blk)[:length] + 1e-30
    assert (np.abs(np.asarray(out)[0] - xb.mean(0).reshape(-1)[:length])
            <= bound).all()


@given(rows=st.integers(1, 3),
       length=st.sampled_from([4, 32, 33, 100, 171, 256]),
       seed=st.integers(0, 10**6))
def test_topk_keeps_largest_and_feeds_back_error(rows, length, seed):
    rate = 0.25
    codec = TopKCompressor(rate=rate)
    x = _payload(rows, length, seed, 1.0)
    k = codec._k(length)
    rt, res = codec.roundtrip(x, jnp.zeros_like(x))
    rn, xn = np.asarray(rt), np.asarray(x)
    assert (np.count_nonzero(rn, axis=1) <= k).all()
    kept = rn != 0
    np.testing.assert_array_equal(rn[kept], xn[kept])  # values verbatim
    # error feedback: residual is exactly what was dropped
    np.testing.assert_allclose(np.asarray(res), xn - rn, atol=1e-7)
    # and the kept entries dominate the dropped ones per row
    for r in range(rows):
        if kept[r].any() and (~kept[r]).any():
            assert np.abs(xn[r][kept[r]]).min() >= \
                np.abs(xn[r][~kept[r]]).max() - 1e-6
