"""Property-based tests (hypothesis) for every registered Compressor:
round-trip error contracts and idempotence — re-encoding a decoded payload
must be a fixed point (up to f32 rounding), which is what makes a codec a
well-defined wire format rather than a one-shot perturbation."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.comms import (IdentityCompressor, Int8Compressor, SignCompressor,
                         TopKCompressor)
from repro.comms.codecs import COMPRESSORS  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

# one representative instance per registered codec CLASS (registry names
# alias: identity/none, int8/q8, sign/1bit); small blocks keep interpret
# mode fast while exercising the padded-tail path
INSTANCES = [IdentityCompressor(), Int8Compressor(block=32),
             SignCompressor(block=32), TopKCompressor(rate=0.25)]


def test_every_registered_codec_is_covered():
    assert {type(c) for c in INSTANCES} == set(COMPRESSORS.values())


def _payload(rows, length, seed, scale):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(rows, length)) * scale, jnp.float32)


LENGTHS = st.sampled_from([1, 7, 31, 32, 33, 64, 100, 171, 256])


@pytest.mark.parametrize("codec", INSTANCES, ids=lambda c: c.name)
@given(rows=st.integers(1, 4), length=LENGTHS,
       seed=st.integers(0, 10**6),
       scale=st.floats(1e-3, 1e3))
def test_roundtrip_and_idempotence(codec, rows, length, seed, scale):
    x = _payload(rows, length, seed, scale)
    once, res = codec.roundtrip(x)
    assert once.shape == x.shape and res is None  # no residual threaded
    twice, _ = codec.roundtrip(once)
    # idempotence: the decoded payload is a fixed point of the codec
    tol = 1e-5 * scale + 1e-6
    np.testing.assert_allclose(np.asarray(twice), np.asarray(once),
                               atol=tol, rtol=1e-5)


@given(rows=st.integers(1, 3), length=LENGTHS,
       seed=st.integers(0, 10**6))
def test_identity_is_exact(rows, length, seed):
    x = _payload(rows, length, seed, 1.0)
    once, _ = IdentityCompressor().roundtrip(x)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(x))


@given(rows=st.integers(1, 3), length=LENGTHS,
       seed=st.integers(0, 10**6))
def test_int8_blockwise_error_bound(rows, length, seed):
    """|x - rt| <= half a quantization step of the block max."""
    blk = 32
    x = _payload(rows, length, seed, 1.0)
    rt, _ = Int8Compressor(block=blk).roundtrip(x)
    xn, rn = np.asarray(x), np.asarray(rt)
    nb = -(-length // blk)
    pad = np.zeros((rows, nb * blk - length), np.float32)
    xb = np.concatenate([xn, pad], 1).reshape(rows, nb, blk)
    rb = np.concatenate([rn, pad], 1).reshape(rows, nb, blk)
    bound = np.abs(xb).max(-1, keepdims=True) / 127.0 * 0.5 + 1e-6
    assert (np.abs(xb - rb) <= bound).all()


@given(rows=st.integers(1, 3), length=LENGTHS,
       seed=st.integers(0, 10**6))
def test_sign_preserves_signs_and_scale(rows, length, seed):
    blk = 32
    x = _payload(rows, length, seed, 1.0)
    rt, _ = SignCompressor(block=blk).roundtrip(x)
    xn, rn = np.asarray(x), np.asarray(rt)
    assert (np.sign(rn) == np.where(xn >= 0, 1.0, -1.0)).all()
    # block magnitudes are mean |x| over REAL entries (padding excluded)
    tail = xn[:, (length // blk) * blk:]
    if tail.size:
        np.testing.assert_allclose(np.abs(rn[:, -1]),
                                   np.abs(tail).mean(1), rtol=1e-5)


@given(rows=st.integers(1, 3),
       length=st.sampled_from([4, 32, 33, 100, 171, 256]),
       seed=st.integers(0, 10**6))
def test_topk_keeps_largest_and_feeds_back_error(rows, length, seed):
    rate = 0.25
    codec = TopKCompressor(rate=rate)
    x = _payload(rows, length, seed, 1.0)
    k = codec._k(length)
    rt, res = codec.roundtrip(x, jnp.zeros_like(x))
    rn, xn = np.asarray(rt), np.asarray(x)
    assert (np.count_nonzero(rn, axis=1) <= k).all()
    kept = rn != 0
    np.testing.assert_array_equal(rn[kept], xn[kept])  # values verbatim
    # error feedback: residual is exactly what was dropped
    np.testing.assert_allclose(np.asarray(res), xn - rn, atol=1e-7)
    # and the kept entries dominate the dropped ones per row
    for r in range(rows):
        if kept[r].any() and (~kept[r]).any():
            assert np.abs(xn[r][kept[r]]).min() >= \
                np.abs(xn[r][~kept[r]]).max() - 1e-6
