"""Shared fixtures. NOTE: no XLA_FLAGS device-count override HERE — it must
be set before jax initializes, so ci.yml exports
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` process-wide (the
in-process mesh-executor tests skip without it) and the subprocess tests
(test_dryrun_small.py, test_executors.py) force it themselves."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
