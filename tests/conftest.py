"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device; only the dry-run
subprocess (tests/test_dryrun_small.py) forces placeholder devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
