"""H-SGD engine semantics (Algorithm 1 / D.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (HSGD, GroupedTopology, HierarchySpec, UniformTopology,
                        contiguous, local_sgd, two_level)
from repro.data import FederatedDataset, label_shard_partition, make_classification
from repro.models import SimpleConfig, SimpleModel
from repro.optim import adam, momentum, sgd

N_WORKERS = 8


@pytest.fixture(scope="module")
def setup():
    x, y = make_classification(0, num_classes=8, dim=16, per_class=40)
    parts = label_shard_partition(y, [[j] for j in range(8)])
    ds = FederatedDataset(x, y, parts)
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=16, hidden=24,
                                     num_classes=8))
    return ds, model


def run_T(model, ds, topology, T=16, lr=0.05, opt=None):
    eng = HSGD(model.loss, opt or sgd(lr), topology, jit=True)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    for t in range(T):
        st, m = eng.step(st, jax.tree.map(jnp.asarray, ds.batch(t, 8)))
    return st, eng


def max_param_diff(a, b):
    d = jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), a, b)
    return max(jax.tree.leaves(d))


def test_n1_group_equals_local_sgd(setup):
    ds, model = setup
    st1, _ = run_T(model, ds, UniformTopology(two_level(N_WORKERS, 1, 8, 4)))
    st2, _ = run_T(model, ds, UniformTopology(local_sgd(N_WORKERS, 4)))
    assert max_param_diff(st1.params, st2.params) == 0.0


def test_i_equals_g_is_local_sgd_p_g(setup):
    ds, model = setup
    st1, _ = run_T(model, ds, UniformTopology(two_level(N_WORKERS, 2, 8, 8)))
    st2, _ = run_T(model, ds, UniformTopology(local_sgd(N_WORKERS, 8)))
    assert max_param_diff(st1.params, st2.params) < 1e-6


def test_uniform_equals_grouped(setup):
    ds, model = setup
    st1, _ = run_T(model, ds, UniformTopology(two_level(N_WORKERS, 2, 8, 4)))
    st2, _ = run_T(model, ds, GroupedTopology(contiguous(N_WORKERS, 2), G=8, I=4))
    assert max_param_diff(st1.params, st2.params) < 1e-5


def test_sync_sgd_replicas_identical(setup):
    ds, model = setup
    st, _ = run_T(model, ds, UniformTopology(two_level(N_WORKERS, 2, 1, 1)), T=5)
    d = jax.tree.map(lambda x: float(jnp.abs(x - x[0:1]).max()), st.params)
    assert max(jax.tree.leaves(d)) == 0.0


def test_replicas_diverge_between_syncs(setup):
    ds, model = setup
    st, _ = run_T(model, ds, UniformTopology(two_level(N_WORKERS, 2, 8, 4)), T=3)
    d = jax.tree.map(lambda x: float(jnp.abs(x - x[0:1]).max()), st.params)
    assert max(jax.tree.leaves(d)) > 1e-4  # non-IID shards => divergence


def test_group_members_equal_after_local_sync(setup):
    """After a local sync (t+1 = I), members of a group share params but
    groups differ (until the global sync)."""
    ds, model = setup
    topo = UniformTopology(two_level(N_WORKERS, 2, 8, 4))
    eng = HSGD(model.loss, sgd(0.05), topo, jit=True)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    for t in range(4):  # t+1=4 = I -> local sync
        st, _ = eng.step(st, jax.tree.map(jnp.asarray, ds.batch(t, 8)))
    w = st.params["h1"]["w"]  # (8, ...)
    g1, g2 = w[:4], w[4:]
    assert float(jnp.abs(g1 - g1[0:1]).max()) < 1e-6
    assert float(jnp.abs(g2 - g2[0:1]).max()) < 1e-6
    assert float(jnp.abs(g1[0] - g2[0]).max()) > 1e-5


def test_heterogeneous_local_periods(setup):
    """Theorem 1 allows different I_i per group; group with I=2 syncs at t+1=2
    while the other (I=4) does not."""
    ds, model = setup
    topo = GroupedTopology(contiguous(N_WORKERS, 2), G=8, I=(2, 4))
    eng = HSGD(model.loss, sgd(0.05), topo, jit=True)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    for t in range(2):
        st, _ = eng.step(st, jax.tree.map(jnp.asarray, ds.batch(t, 8)))
    w = st.params["h1"]["w"]
    assert float(jnp.abs(w[:4] - w[0:1]).max()) < 1e-6     # group 1 synced
    assert float(jnp.abs(w[4:] - w[4:5]).max()) > 1e-5     # group 2 did not


def test_three_level_subsumption(setup):
    """Algorithm D.1 break semantics: at t+1 = P_1 every level collapses to
    the global average; at t+1 = P_2 only the level-2 subtrees align."""
    ds, model = setup
    spec = HierarchySpec(group_sizes=(2, 2, 2), periods=(8, 4, 2))
    topo = UniformTopology(spec)
    eng = HSGD(model.loss, sgd(0.05), topo, jit=True)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    for t in range(4):  # t+1=4 = P_2
        st, _ = eng.step(st, jax.tree.map(jnp.asarray, ds.batch(t, 8)))
    w = st.params["h1"]["w"].reshape(2, 4, -1)
    for i in range(2):
        assert float(jnp.abs(w[i] - w[i, 0:1]).max()) < 1e-6
    assert float(jnp.abs(w[0, 0] - w[1, 0]).max()) > 1e-5
    for t in range(4, 8):  # t+1=8 = P_1: global
        st, _ = eng.step(st, jax.tree.map(jnp.asarray, ds.batch(t, 8)))
    w = st.params["h1"]["w"]
    assert float(jnp.abs(w - w[0:1]).max()) < 1e-6


def test_momentum_and_adam_states_aggregate(setup):
    ds, model = setup
    for opt in (momentum(0.05), adam(1e-2)):
        topo = UniformTopology(two_level(N_WORKERS, 2, 4, 2))
        eng = HSGD(model.loss, opt, topo, jit=True)
        st = eng.init(jax.random.PRNGKey(0), model.init)
        for t in range(4):
            st, _ = eng.step(st, jax.tree.map(jnp.asarray, ds.batch(t, 8)))
        m = st.opt_state["m"]["h1"]["w"]
        assert float(jnp.abs(m - m[0:1]).max()) < 1e-6  # t+1=4=G -> all equal


def test_loss_decreases_under_hsgd(setup):
    ds, model = setup
    topo = UniformTopology(two_level(N_WORKERS, 2, 8, 4))
    eng = HSGD(model.loss, sgd(0.1), topo, jit=True)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    gb = jax.tree.map(jnp.asarray, ds.global_batch(512))
    l0 = float(model.loss(eng.mean_params(st), gb)[0])
    for t in range(40):
        st, _ = eng.step(st, jax.tree.map(jnp.asarray, ds.batch(t, 16)))
    l1 = float(model.loss(eng.mean_params(st), gb)[0])
    assert l1 < l0 - 0.3, (l0, l1)


def test_partial_participation_semantics(setup):
    """Non-participants keep their params between syncs; at a sync they
    receive the participants' average (paper Appendix E semantics)."""
    import numpy as np
    from repro.core import sample_participation
    ds, model = setup
    topo = UniformTopology(two_level(N_WORKERS, 2, 8, 4))
    eng = HSGD(model.loss, sgd(0.05), topo, jit=True)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    mask = np.zeros(N_WORKERS, bool)
    mask[[0, 1, 4, 5]] = True   # 2 participants per group
    p_before = jax.tree.map(lambda x: x.copy(), st.params)
    # 3 pure-local steps: non-participants must not move at all
    for t in range(3):
        st, _ = eng.step(st, jax.tree.map(jnp.asarray, ds.batch(t, 8)),
                         mask=mask)
    w = st.params["h1"]["w"]
    w0 = p_before["h1"]["w"]
    assert float(jnp.abs(w[2] - w0[2]).max()) == 0.0
    assert float(jnp.abs(w[3] - w0[3]).max()) == 0.0
    assert float(jnp.abs(w[0] - w0[0]).max()) > 1e-5
    # 4th step = local sync: every group member gets the participants' mean
    st, _ = eng.step(st, jax.tree.map(jnp.asarray, ds.batch(3, 8)), mask=mask)
    w = st.params["h1"]["w"]
    assert float(jnp.abs(w[:4] - w[0:1]).max()) < 1e-6
    assert float(jnp.abs(w[4:] - w[4:5]).max()) < 1e-6


def test_participation_grouped_topology(setup):
    import numpy as np
    ds, model = setup
    topo = GroupedTopology(contiguous(N_WORKERS, 2), G=4, I=2)
    eng = HSGD(model.loss, sgd(0.05), topo, jit=True)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    mask = np.array([True, True, False, False, True, False, True, False])
    for t in range(4):  # includes a local sync (t+1=2) and global (t+1=4)
        st, _ = eng.step(st, jax.tree.map(jnp.asarray, ds.batch(t, 8)),
                         mask=mask)
    w = st.params["h1"]["w"]
    # after global sync everyone holds the same model
    assert float(jnp.abs(w - w[0:1]).max()) < 1e-6


def test_sample_participation_at_least_one_per_group():
    from repro.core import contiguous as contig, sample_participation
    g = contig(12, 3)
    for seed in range(5):
        m = sample_participation(g, 0.25, seed)
        for i in range(3):
            assert m[g.members(i)].sum() >= 1
    m2 = sample_participation((2, 4), 0.5, 0)
    assert m2.shape == (8,) and m2[:4].sum() >= 1 and m2[4:].sum() >= 1


def test_grad_accumulation_equals_large_batch(setup):
    """SGD is linear in the gradient: accum_steps=2 over a batch equals one
    step on the full batch, bitwise-ish."""
    ds, model = setup
    topo = UniformTopology(two_level(N_WORKERS, 2, 4, 2))
    e1 = HSGD(model.loss, sgd(0.05), topo, jit=True, accum_steps=1)
    e2 = HSGD(model.loss, sgd(0.05), topo, jit=True, accum_steps=2)
    s1 = e1.init(jax.random.PRNGKey(0), model.init)
    s2 = e2.init(jax.random.PRNGKey(0), model.init)
    for t in range(4):
        b = jax.tree.map(jnp.asarray, ds.batch(t, 8))
        s1, m1 = e1.step(s1, b)
        s2, m2 = e2.step(s2, b)
    assert max_param_diff(s1.params, s2.params) < 1e-6
