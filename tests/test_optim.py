"""Optimizer + schedule unit tests vs closed forms."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam, constant, cosine, linear_warmup, momentum, sgd


def _step(opt, params, grads, state):
    upd, state = opt.update(grads, state, params)
    return jax.tree.map(jnp.add, params, upd), state


def test_sgd_closed_form():
    opt = sgd(0.1)
    p = {"w": jnp.ones(3)}
    g = {"w": jnp.full(3, 2.0)}
    s = opt.init(p)
    p, s = _step(opt, p, g, s)
    np.testing.assert_allclose(np.asarray(p["w"]), 1.0 - 0.1 * 2.0)


def test_momentum_closed_form():
    opt = momentum(0.1, beta=0.5)
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    s = opt.init(p)
    p, s = _step(opt, p, g, s)   # m=1, p=-0.1
    p, s = _step(opt, p, g, s)   # m=1.5, p=-0.25
    np.testing.assert_allclose(np.asarray(p["w"]), -0.25, rtol=1e-6)


def test_adam_first_step_magnitude():
    opt = adam(1e-3)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.asarray([1.0, -2.0, 0.5, 10.0])}
    s = opt.init(p)
    p, s = _step(opt, p, g, s)
    # bias-corrected first step = -lr * sign(g)
    np.testing.assert_allclose(np.asarray(p["w"]),
                               -1e-3 * np.sign([1, -2, 0.5, 10]), rtol=1e-4)


def test_sgd_with_schedule():
    sched = linear_warmup(1.0, 4)
    opt = sgd(sched)
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    s = opt.init(p)
    deltas = []
    for _ in range(4):
        p2, s = _step(opt, p, g, s)
        deltas.append(float((p2["w"] - p["w"])[0]))
        p = p2
    np.testing.assert_allclose(deltas, [-0.25, -0.5, -0.75, -1.0], rtol=1e-6)


def test_cosine_schedule_endpoints():
    f = cosine(1.0, total_steps=100, warmup_steps=0, final_fraction=0.1)
    assert abs(float(f(jnp.asarray(0))) - 1.0) < 0.01
    assert abs(float(f(jnp.asarray(100))) - 0.1) < 0.01
    assert float(f(jnp.asarray(50))) > 0.1


def test_constant():
    assert float(constant(0.3)(jnp.asarray(5))) == np.float32(0.3)
