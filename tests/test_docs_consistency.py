"""Docs-consistency gate: every root-level ``*.md`` document referenced
from source must actually exist.

Four modules cited a ``DESIGN.md`` that did not exist for several PRs
(theory.py's erratum, dryrun.py's shape-skip table, serving/engine.py's
continuous-batching note, models/layers.py's ragged-dispatch note) — a
drift nothing caught because doc references live in docstrings and
comments, invisible to the import graph.  This test (and the matching CI
step) scans ``src/`` and ``benchmarks/`` for root-document references
(UPPERCASE ``NAME.md`` tokens, the repo's convention for root docs) and
fails on any dangling one, with the offending file:line locations.
"""
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# UPPERCASE .md names are root documents (README.md, DESIGN.md, ...);
# lowercase .md tokens are prose ("a *.md file"), not references.
_REF = re.compile(r"\b([A-Z][A-Z0-9_]+\.md)\b")


def iter_doc_refs():
    for sub in ("src", "benchmarks"):
        for path in sorted((ROOT / sub).rglob("*.py")):
            for lineno, line in enumerate(
                    path.read_text(encoding="utf-8").splitlines(), 1):
                for name in _REF.findall(line):
                    yield name, f"{path.relative_to(ROOT)}:{lineno}"


def test_no_dangling_doc_references():
    missing = {}
    for name, where in iter_doc_refs():
        if not (ROOT / name).is_file():
            missing.setdefault(name, []).append(where)
    assert not missing, (
        "source references root documents that do not exist:\n" +
        "\n".join(f"  {name} <- {', '.join(at)}"
                  for name, at in sorted(missing.items())))


def test_the_gate_actually_sees_references():
    """Guard the guard: the scan must find the known root-doc references
    (if the regex or the walk breaks, the gate would pass vacuously)."""
    seen = {name for name, _ in iter_doc_refs()}
    assert "DESIGN.md" in seen, "expected DESIGN.md references in src/"
