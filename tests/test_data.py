"""Data pipeline tests: partitions, determinism, stream seekability."""
import numpy as np

from repro.data import (FederatedDataset, dirichlet_partition,
                        label_shard_partition, make_classification,
                        synth_lm_batch, TokenStream)


def test_label_shard_partition_exact():
    _, y = make_classification(0, num_classes=4, dim=4, per_class=50)
    parts = label_shard_partition(y, [[0, 1], [2, 3]])
    assert set(np.unique(y[parts[0]])) == {0, 1}
    assert set(np.unique(y[parts[1]])) == {2, 3}
    assert len(np.intersect1d(parts[0], parts[1])) == 0
    assert len(parts[0]) + len(parts[1]) == len(y)


def test_shared_label_split_evenly():
    _, y = make_classification(1, num_classes=2, dim=4, per_class=100)
    parts = label_shard_partition(y, [[0], [0], [1]])
    assert abs(len(parts[0]) - len(parts[1])) <= 1
    assert set(np.unique(y[parts[2]])) == {1}


def test_dirichlet_partition_covers_all():
    _, y = make_classification(2, num_classes=5, dim=4, per_class=40)
    parts = dirichlet_partition(y, 4, alpha=0.5)
    assert sum(len(p) for p in parts) == len(y)
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == len(y)


def test_federated_batch_deterministic():
    x, y = make_classification(0, num_classes=4, dim=4, per_class=30)
    parts = label_shard_partition(y, [[j % 4] for j in range(8)])
    ds = FederatedDataset(x, y, parts)
    b1 = ds.batch(step=3, batch_size=4)
    b2 = ds.batch(step=3, batch_size=4)
    np.testing.assert_array_equal(b1["x"], b2["x"])
    b3 = ds.batch(step=4, batch_size=4)
    assert not np.array_equal(b1["x"], b3["x"])
    assert b1["x"].shape == (8, 4, 4)


def test_token_stream_seekable_and_learnable():
    b1 = synth_lm_batch(0, 7, batch=2, seq_len=16, vocab=97)
    b2 = synth_lm_batch(0, 7, batch=2, seq_len=16, vocab=97)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # structure: ~75% of transitions follow t' = 7t+1 mod V
    toks = np.asarray(b1["tokens"])
    tgts = np.asarray(b1["targets"])
    np.testing.assert_array_equal(toks[:, 1:], tgts[:, :-1])
    frac = np.mean(tgts == (toks * 7 + 1) % 97)
    assert frac > 0.6


def test_stream_worker_axis():
    ts = TokenStream(seed=0, batch=2, seq_len=8, vocab=31, n_workers=3)
    b = ts(0)
    assert b["tokens"].shape == (3, 2, 8)
    assert not np.array_equal(np.asarray(b["tokens"][0]),
                              np.asarray(b["tokens"][1]))
