"""Data pipeline tests: partitions, determinism, stream seekability."""
import numpy as np
import pytest

from repro.data import (FederatedDataset, PopulationShards,
                        dirichlet_partition, label_shard_partition,
                        make_classification, synth_lm_batch, TokenStream)


def test_label_shard_partition_exact():
    _, y = make_classification(0, num_classes=4, dim=4, per_class=50)
    parts = label_shard_partition(y, [[0, 1], [2, 3]])
    assert set(np.unique(y[parts[0]])) == {0, 1}
    assert set(np.unique(y[parts[1]])) == {2, 3}
    assert len(np.intersect1d(parts[0], parts[1])) == 0
    assert len(parts[0]) + len(parts[1]) == len(y)


def test_shared_label_split_evenly():
    _, y = make_classification(1, num_classes=2, dim=4, per_class=100)
    parts = label_shard_partition(y, [[0], [0], [1]])
    assert abs(len(parts[0]) - len(parts[1])) <= 1
    assert set(np.unique(y[parts[2]])) == {1}


def test_dirichlet_partition_covers_all():
    _, y = make_classification(2, num_classes=5, dim=4, per_class=40)
    parts = dirichlet_partition(y, 4, alpha=0.5)
    assert sum(len(p) for p in parts) == len(y)
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == len(y)


def test_federated_batch_deterministic():
    x, y = make_classification(0, num_classes=4, dim=4, per_class=30)
    parts = label_shard_partition(y, [[j % 4] for j in range(8)])
    ds = FederatedDataset(x, y, parts)
    b1 = ds.batch(step=3, batch_size=4)
    b2 = ds.batch(step=3, batch_size=4)
    np.testing.assert_array_equal(b1["x"], b2["x"])
    b3 = ds.batch(step=4, batch_size=4)
    assert not np.array_equal(b1["x"], b3["x"])
    assert b1["x"].shape == (8, 4, 4)


def test_partition_validation_actionable():
    _, y = make_classification(2, num_classes=5, dim=4, per_class=40)
    with pytest.raises(ValueError, match="alpha > 0"):
        dirichlet_partition(y, 4, alpha=0.0)
    with pytest.raises(ValueError, match="alpha > 0"):
        dirichlet_partition(y, 4, alpha=-1.0)
    with pytest.raises(ValueError, match="n_workers >= 1"):
        dirichlet_partition(y, 0, alpha=0.5)
    with pytest.raises(ValueError, match="one label set per worker"):
        label_shard_partition(y, [[0], [1]], n_workers=4)
    with pytest.raises(ValueError, match="do not occur in y"):
        label_shard_partition(y, [[0], [9]])


def test_require_workers():
    x, y = make_classification(0, num_classes=4, dim=4, per_class=30)
    parts = label_shard_partition(y, [[j % 4] for j in range(8)])
    ds = FederatedDataset(x, y, parts)
    assert ds.require_workers(8) is ds  # chains
    with pytest.raises(ValueError, match="topology expects n=4"):
        ds.require_workers(4)
    with pytest.raises(ValueError, match="are empty"):
        FederatedDataset(x, y, parts[:7] + [np.empty(0, np.int64)]) \
            .require_workers(8)


def test_population_shards_pure_and_bounded():
    ps = PopulationShards(population=10**9, num_classes=6, dim=8, seed=4)
    ids = np.array([3, 10**8, -1])
    b1 = ps.batch(ids, step=5, batch_size=7)
    b2 = ps.batch(ids, step=5, batch_size=7)
    np.testing.assert_array_equal(b1["x"], b2["x"])
    np.testing.assert_array_equal(b1["y"], b2["y"])
    assert b1["x"].shape == (3, 7, 8) and b1["x"].dtype == np.float32
    assert b1["y"].shape == (3, 7) and np.isfinite(b1["x"]).all()
    b3 = ps.batch(ids, step=6, batch_size=7)
    assert not np.array_equal(b1["x"], b3["x"])
    # every sample's label comes from the client's declared shard
    for j, cid in enumerate(ids):
        assert set(b1["y"][j]) <= set(ps.client_labels(cid).tolist())
    # size law agrees with the sampler's default (weights match data)
    from repro.population.sampler import default_client_sizes
    law = default_client_sizes(4)
    assert ps.client_size(3) == int(law(3))
    assert ps.client_size(-1) == 0
    with pytest.raises(ValueError, match="outside the declared population"):
        ps.client_size(10**9)


def test_population_shards_validation():
    with pytest.raises(ValueError, match="population"):
        PopulationShards(population=0)
    with pytest.raises(ValueError, match="labels_per_client"):
        PopulationShards(population=10, num_classes=4, labels_per_client=5)


def test_token_stream_seekable_and_learnable():
    b1 = synth_lm_batch(0, 7, batch=2, seq_len=16, vocab=97)
    b2 = synth_lm_batch(0, 7, batch=2, seq_len=16, vocab=97)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # structure: ~75% of transitions follow t' = 7t+1 mod V
    toks = np.asarray(b1["tokens"])
    tgts = np.asarray(b1["targets"])
    np.testing.assert_array_equal(toks[:, 1:], tgts[:, :-1])
    frac = np.mean(tgts == (toks * 7 + 1) % 97)
    assert frac > 0.6


def test_stream_worker_axis():
    ts = TokenStream(seed=0, batch=2, seq_len=8, vocab=31, n_workers=3)
    b = ts(0)
    assert b["tokens"].shape == (3, 2, 8)
    assert not np.array_equal(np.asarray(b["tokens"][0]),
                              np.asarray(b["tokens"][1]))
