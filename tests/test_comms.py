"""The comms subsystem: FlatBucket fusion, codec kernels vs oracles, the
registry, WireStats accounting, and engine integration (sim executor).

Sim<->mesh comms equivalence lives in tests/test_executors.py (needs 8
devices); codec round-trip/idempotence property tests in
tests/test_comms_properties.py (hypothesis-optional)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comms import (Comms, Compressor, FlatBucket, IdentityCompressor,
                         Int8Compressor, SignCompressor, TopKCompressor,
                         WireArray, WireStats, make_comms, make_compressor,
                         register_compressor)
from repro.comms.codecs import COMPRESSORS
from repro.core import (HSGD, GroupedTopology, HierarchySpec, SyncEvent,
                        contiguous, make_aggregator, make_topology)
from repro.data import FederatedDataset, label_shard_partition, make_classification
from repro.kernels.comms import (int8_dequantize, int8_quantize,
                                 int8_scale_quantize, sign_pack, sign_unpack,
                                 topk_decode_reduce)
from repro.kernels.ref import (int8_ref, int8_scale_quant_ref, sign_ref,
                               topk_reduce_ref)
from repro.models import SimpleConfig, SimpleModel
from repro.optim import sgd

N = 8


@pytest.fixture(scope="module")
def setup():
    x, y = make_classification(0, num_classes=8, dim=16, per_class=40)
    parts = label_shard_partition(y, [[j] for j in range(8)])
    ds = FederatedDataset(x, y, parts)
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=16, hidden=24,
                                     num_classes=8))
    return ds, model


def trajectory(ds, model, topo, comms, T=16, executor="sim"):
    eng = HSGD(model.loss, sgd(0.05), topo, executor=executor, comms=comms)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    st, hist = eng.run_rounds(
        st, lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8)), T)
    return eng, st, hist


def max_diff(a, b):
    d = jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), a, b)
    return max(jax.tree.leaves(d))


# ---------------------------------------------------------------------------
# FlatBucket
# ---------------------------------------------------------------------------
def tree_mixed(n=4):
    rng = np.random.default_rng(0)
    return {
        "w1": jnp.asarray(rng.normal(size=(n, 5, 3)), jnp.float32),
        "b1": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
        "h": jnp.asarray(rng.normal(size=(n, 2, 2)), jnp.bfloat16),
        "s": jnp.asarray(rng.normal(size=(n,)), jnp.float32),
    }


def test_flatbucket_roundtrip_mixed_dtypes():
    tree = tree_mixed()
    fb = FlatBucket.plan(tree)
    bufs = fb.flatten(tree)
    assert sorted(bufs) == ["bfloat16", "float32"]
    assert bufs["float32"].shape == (4, 15 + 3 + 1)
    assert bufs["bfloat16"].shape == (4, 4)
    out = fb.unflatten(bufs)
    assert max_diff(tree, out) == 0.0
    assert jax.tree.map(lambda x: x.dtype, out) == \
        jax.tree.map(lambda x: x.dtype, tree)


def test_flatbucket_plan_is_cached():
    tree = tree_mixed()
    assert FlatBucket.plan(tree) is FlatBucket.plan(tree_mixed())


def test_flatbucket_per_shard_worker_axis():
    """The mesh executor flattens (1, ...) shards with their own plan."""
    tree = jax.tree.map(lambda x: x[:1], tree_mixed())
    fb = FlatBucket.plan(tree)
    assert fb.lengths == FlatBucket.plan(tree_mixed()).lengths
    assert max_diff(tree, fb.unflatten(fb.flatten(tree))) == 0.0


# ---------------------------------------------------------------------------
# kernels vs jnp oracles (interpret mode)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("r,c,blk", [(3, 100, 32), (1, 64, 64), (4, 37, 16),
                                     (2, 8, 8), (1, 7, 8)])
def test_int8_kernels_match_ref(r, c, blk):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    q, s = int8_quantize(x, block=blk, interpret=True)
    qr, sr, rtr = int8_ref(x, blk)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    y = int8_dequantize(q, s, block=blk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(rtr), rtol=1e-6)
    # per-block max-scale error bound
    xb = np.asarray(x)
    err = np.abs(np.asarray(y) - xb).max()
    assert err <= np.abs(xb).max() / 127.0 * 0.5 + 1e-6


@pytest.mark.parametrize("r,c,blk", [(3, 100, 32), (1, 64, 64), (4, 37, 16),
                                     (2, 8, 8)])
def test_sign_kernels_match_ref(r, c, blk):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    bits, s = sign_pack(x, block=blk, interpret=True)
    assert bits.dtype == jnp.uint8
    sr, rtr = sign_ref(x, blk)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    y = sign_unpack(bits, s, size=c, block=blk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(rtr), rtol=1e-6)
    # decoded values are exactly +-(block mean |x|), sign-aligned with x
    assert (np.sign(np.asarray(y)) == np.where(np.asarray(x) >= 0, 1, -1)).all()


@pytest.mark.parametrize("r,c,blk", [(3, 100, 32), (1, 64, 64), (4, 37, 16),
                                     (2, 8, 8), (1, 7, 8)])
def test_int8_scale_quantize_matches_ref(r, c, blk):
    """The shared-scale quantizer of the compressed allreduce: the caller
    supplies per-block scales (the group amax under the collective), the
    kernel must reproduce the jnp oracle exactly — including a zero scale
    mapping to q = 0."""
    rng = np.random.default_rng(4)
    nb = -(-c // blk)
    x = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    scale = jnp.asarray(np.abs(rng.normal(size=(r, nb))), jnp.float32)
    scale = scale.at[:, 0].set(0.0)  # exercise the zero-scale branch
    q = int8_scale_quantize(x, scale, block=blk, interpret=True)
    assert q.dtype == jnp.int8 and q.shape == (r, c)
    np.testing.assert_array_equal(np.asarray(q),
                                  np.asarray(int8_scale_quant_ref(x, scale,
                                                                  blk)))
    assert not np.asarray(q[:, :min(blk, c)]).any()  # zero scale -> q = 0


@pytest.mark.parametrize("m,k,size,blk", [(8, 4, 100, 32), (1, 1, 7, 8),
                                          (16, 15, 244, 64), (3, 10, 64, 64)])
def test_topk_decode_reduce_matches_ref(m, k, size, blk):
    """The fused Pallas decode-reduce behind the top-k ragged all-gather:
    M sparse (values, indices) payloads scatter-added into one dense
    (size,) f32 sum.  With unique indices the match against the jnp scatter
    oracle is bitwise (each output element is a single payload value);
    colliding indices accumulate, in a summation order that may differ from
    the oracle's scatter order by f32 rounding (1 ulp)."""
    rng = np.random.default_rng(5)
    vals = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    uniq = jnp.asarray(rng.permutation(size)[:min(m * k, size)], jnp.int32)
    if uniq.size == m * k:  # all indices distinct -> bitwise
        out = topk_decode_reduce(vals, uniq.reshape(m, k), size=size,
                                 block=blk, interpret=True)
        assert out.shape == (size,) and out.dtype == jnp.float32
        np.testing.assert_array_equal(
            np.asarray(out),
            np.asarray(topk_reduce_ref(vals, uniq.reshape(m, k), size)))
    idx = jnp.asarray(rng.integers(0, size, size=(m, k)), jnp.int32)
    out = topk_decode_reduce(vals, idx, size=size, block=blk, interpret=True)
    assert out.shape == (size,) and out.dtype == jnp.float32
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(topk_reduce_ref(vals, idx, size)),
        rtol=1e-6, atol=1e-6)


def test_comm_kernels_public_entry_points():
    """ops.py exports with interpret-mode auto-selection + block shrinking."""
    from repro.kernels import (int8_dequantize as deq, int8_quantize as quant,
                               sign_pack as sp, sign_unpack as su)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(2, 200)),
                    jnp.float32)
    q, s = quant(x)          # interpret auto-selected off-TPU, block shrunk
    assert q.shape == (2, 200) and s.shape[0] == 2
    y = deq(q, s)
    assert np.abs(np.asarray(y) - np.asarray(x)).max() < 0.05
    bits, ss = sp(x)
    ys = su(bits, ss, size=200)
    assert ys.shape == (2, 200)


# ---------------------------------------------------------------------------
# codec registry
# ---------------------------------------------------------------------------
def test_make_compressor_registry():
    assert isinstance(make_compressor(None), IdentityCompressor)
    assert isinstance(make_compressor("int8"), Int8Compressor)
    assert isinstance(make_compressor("sign", block=64), SignCompressor)
    assert isinstance(make_compressor("topk", rate=0.5), TopKCompressor)
    inst = Int8Compressor(block=64)
    assert make_compressor(inst) is inst
    with pytest.raises(KeyError):
        make_compressor("zstd")
    with pytest.raises(ValueError, match="constructing by name"):
        make_compressor(inst, block=32)

    class Noop(IdentityCompressor):
        name = "noop"

    register_compressor("noop", Noop)
    try:
        assert isinstance(make_compressor("NOOP"), Noop)
    finally:
        COMPRESSORS.pop("noop")


def test_make_comms_spellings():
    assert make_comms(None) is None
    c = make_comms("int8")
    assert isinstance(c, Comms) and isinstance(c.codec, Int8Compressor)
    assert make_comms(c) is c
    c2 = make_comms(SignCompressor(block=64))
    assert isinstance(c2.codec, SignCompressor)
    assert make_comms(bucket=True).bucket  # kwargs-only: identity + buckets


def test_make_aggregator_rejects_sync_dtype_on_instance():
    """Regression: sync_dtype was silently ignored when an instance was
    passed — now a clear ValueError."""
    inst = make_aggregator("mean")
    with pytest.raises(ValueError, match="sync_dtype"):
        make_aggregator(inst, sync_dtype="bfloat16")
    assert make_aggregator(inst) is inst  # no sync_dtype: unchanged


# ---------------------------------------------------------------------------
# WireStats
# ---------------------------------------------------------------------------
def test_wirestats_per_level_counts_uniform():
    topo = make_topology("uniform", spec=HierarchySpec((2, 2, 2), (8, 4, 2)))
    comms = Comms("identity")
    params = jax.tree.map(lambda x: jnp.broadcast_to(x, (8,) + x.shape),
                          {"w": jnp.zeros((10,), jnp.float32)})
    payload, n_el = comms.payload_spec(params)
    ws = WireStats(topo, payload, n_el)
    assert ws.payload_bytes == 40 and ws.f32_bytes == 40
    # level-l sync moves one payload per tree edge at tiers >= l
    assert ws.payload_count(SyncEvent(level=1)) == 2 + 4 + 8
    assert ws.payload_count(SyncEvent(level=2)) == 4 + 8
    assert ws.payload_count(SyncEvent(level=3)) == 8
    per = ws.per_level()
    assert per["L1"]["bytes_per_sync"] == 14 * 40
    assert per["L3"]["period"] == 2
    # schedule totals: periods (8,4,2) over 8 steps -> L3 at t=1,5 (2x),
    # L2 at t=3 (1x), L1 at t=7 (1x)
    sb = ws.step_bytes(8)
    assert sb == [0, 8 * 40, 0, 12 * 40, 0, 8 * 40, 0, 14 * 40]
    s = ws.summary(8)
    assert s["total_bytes"] == sum(sb)


def test_wirestats_grouped_topology():
    g = contiguous(6, 2)  # 2 groups of 3
    topo = GroupedTopology(g, G=8, I=(2, 4))
    ws = WireStats(topo, (), 0)
    assert ws.payload_count(SyncEvent(level=1)) == 6 + 2
    assert ws.payload_count(SyncEvent(level=2)) == 6
    assert ws.payload_count(SyncEvent(level=2, groups=(True, False))) == 3
    # heterogeneous periods: per_level costs the ACTUAL (partial) events —
    # I=(2, 8): three (True, False) L2 events per period, never a full one
    topo2 = GroupedTopology(g, G=8, I=(2, 8))
    wa = WireArray("value", (10,), "float32")
    ws2 = WireStats(topo2, (wa,), 10)
    per = ws2.per_level()
    assert per["L2"]["payloads_per_sync"] == 3       # one group of 3
    assert per["L2"]["syncs_per_period"] == 3
    assert per["L2"]["bytes_per_sync"] == 3 * wa.nbytes
    assert per["L1"]["payloads_per_sync"] == 8
    # summary and per-step history agree
    assert sum(ws2.step_bytes(8)) == \
        3 * per["L2"]["bytes_per_sync"] + per["L1"]["bytes_per_sync"]


def test_wirestats_codec_ratios():
    comms8 = Comms("int8")
    commsS = Comms("sign")
    params = {"w": jnp.zeros((8, 4096), jnp.float32)}
    topo = make_topology("two_level", n=8, N=2, G=8, I=2)
    for comms, lo, hi in [(comms8, 3.8, 4.1), (commsS, 28.0, 33.0)]:
        payload, n_el = comms.payload_spec(params)
        ws = WireStats(topo, payload, n_el)
        assert lo < ws.compression_ratio < hi, (comms, ws.compression_ratio)


# ---------------------------------------------------------------------------
# engine integration (sim)
# ---------------------------------------------------------------------------
def test_comms_off_is_default_and_stateless(setup):
    ds, model = setup
    topo = make_topology("two_level", n=N, N=2, G=8, I=4)
    eng, st, hist = trajectory(ds, model, topo, None)
    assert eng.comms is None and st.comms is None
    assert eng.wire_stats(st) is None
    assert "wire_bytes" not in hist[0]


def test_identity_bucket_is_bitwise(setup):
    """FlatBucket + identity codec only changes layout, never values."""
    ds, model = setup
    mk = lambda: make_topology("uniform", spec=HierarchySpec((2, 4), (8, 4)))
    _, s0, h0 = trajectory(ds, model, mk(), None)
    e1, s1, h1 = trajectory(ds, model, mk(), Comms())
    assert max_diff(s0.params, s1.params) == 0.0
    assert [r["ce"] for r in h0] == [r["ce"] for r in h1]


def test_sync_operand_count_is_o_dtypes(setup):
    """The jaxpr of the fused aggregation shows O(dtypes) sync reductions
    instead of O(leaves) — the FlatBucket claim, verified on the lowered
    program (not wall-clock) via the repro.analysis walker."""
    from repro.analysis import trace
    ds, model = setup
    topo = make_topology("uniform", spec=HierarchySpec((2, 4), (8, 4)))
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (N,) + x.shape),
        model.init(jax.random.PRNGKey(0)))
    n_leaves = len(jax.tree.leaves(params))
    assert n_leaves >= 4
    ev = SyncEvent(level=1)

    comms = Comms()
    plain = trace(lambda t: topo.aggregate(t, ev), params)
    fused = trace(
        lambda t: comms.sync(t, lambda b: topo.aggregate(b, ev))[0], params)
    assert plain.count("reduce_sum") == n_leaves
    assert fused.count("reduce_sum") == 1  # one f32 bucket


def test_int8_comms_trains(setup):
    ds, model = setup
    topo = make_topology("uniform", spec=HierarchySpec((2, 4), (8, 4)))
    eng, st, hist = trajectory(ds, model, topo, "int8")
    assert np.isfinite(hist[-1]["ce"])
    ws = eng.wire_stats(st)
    assert 3.8 < ws.compression_ratio < 4.1
    # history wire_bytes matches the static schedule accounting
    assert [r["wire_bytes"] for r in hist] == ws.step_bytes(len(hist))


def test_sign_comms_trains(setup):
    ds, model = setup
    topo = make_topology("uniform", spec=HierarchySpec((2, 4), (8, 4)))
    eng, st, hist = trajectory(ds, model, topo, Comms("sign", block=256))
    assert np.isfinite(hist[-1]["ce"])


def test_codec_composes_with_sign_aggregator(setup):
    """Codec (wire format) and aggregator (mean rule) are orthogonal."""
    ds, model = setup
    topo = make_topology("uniform", spec=HierarchySpec((2, 4), (8, 4)),
                         aggregator="sign")
    eng, st, hist = trajectory(ds, model, topo, "int8")
    assert np.isfinite(hist[-1]["ce"])


def test_comms_on_grouped_topology(setup):
    ds, model = setup
    topo = GroupedTopology(contiguous(N, 2), G=8, I=4)
    eng, st, hist = trajectory(ds, model, topo, "int8")
    assert np.isfinite(hist[-1]["ce"])
    assert hist[7]["wire_bytes"] > hist[3]["wire_bytes"] > 0


def test_partial_group_events_keep_nonsyncing_workers(setup):
    """Regression: a lossy codec must not touch workers a partial-group
    event did not sync.  With I=(2, 8) group 1 never syncs before t=8, so
    its workers' params (and residuals) stay bitwise equal to the comms-off
    trajectory through t=7."""
    ds, model = setup
    mk = lambda: GroupedTopology(contiguous(N, 2), G=8, I=(2, 8))
    # group 0 syncs at t+1 in {2,4,6}; group 1 first syncs at t+1=8
    assert mk().event_at(1).groups == (True, False)
    _, s_off, _ = trajectory(ds, model, mk(), None, T=7)
    eng, s_on, _ = trajectory(ds, model, mk(), Comms("topk", rate=0.1), T=7)
    g1 = jax.tree.map(lambda x: x[4:], s_off.params)
    g1c = jax.tree.map(lambda x: x[4:], s_on.params)
    assert max_diff(g1, g1c) == 0.0
    # group 1's error-feedback residual is unconsumed (still zero)
    res = jax.tree.leaves(s_on.comms)
    assert all(float(jnp.abs(r[4:]).max()) == 0 for r in res)
    assert any(float(jnp.abs(r[:4]).max()) > 0 for r in res)
    # group 0 DID go through the codec
    g0 = jax.tree.map(lambda x: x[:4], s_off.params)
    g0c = jax.tree.map(lambda x: x[:4], s_on.params)
    assert max_diff(g0, g0c) > 0


def test_wire_stats_counts_optimizer_moments(setup):
    """Regression: aggregate_opt_state puts the moments on the wire, so the
    accounting must include them (sgd has none; momentum doubles params)."""
    from repro.optim import momentum
    ds, model = setup
    mk = lambda: make_topology("uniform", spec=HierarchySpec((2, 4), (8, 4)))
    e_sgd = HSGD(model.loss, sgd(0.05), mk(), comms="int8")
    s_sgd = e_sgd.init(jax.random.PRNGKey(0), model.init)
    e_mom = HSGD(model.loss, momentum(0.05), mk(), comms="int8")
    s_mom = e_mom.init(jax.random.PRNGKey(0), model.init)
    b_sgd = e_sgd.wire_stats(s_sgd).payload_bytes
    b_mom = e_mom.wire_stats(s_mom).payload_bytes
    assert b_mom == 2 * b_sgd
    names = {a.name for a in e_mom.wire_stats(s_mom).payload}
    assert any(n.startswith("moments.") for n in names)
    # opting out of moment aggregation drops them from the accounting
    e_solo = HSGD(model.loss, momentum(0.05), mk(), comms="int8",
                  aggregate_opt_state=False)
    s_solo = e_solo.init(jax.random.PRNGKey(0), model.init)
    assert e_solo.wire_stats(s_solo).payload_bytes == b_sgd


def test_topk_error_feedback_state(setup):
    ds, model = setup
    mk = lambda: make_topology("uniform", spec=HierarchySpec((2, 4), (8, 4)))
    eng, st, hist = trajectory(ds, model, mk(), Comms("topk", rate=0.25))
    assert st.comms is not None
    res = jax.tree.leaves(st.comms)
    assert all(r.dtype == jnp.float32 for r in res)
    assert max(float(jnp.abs(r).max()) for r in res) > 0  # EF accumulated
    assert np.isfinite(hist[-1]["ce"])
    # rate=1 keeps everything: EF machinery must be exactly transparent
    _, s_full, _ = trajectory(ds, model, mk(), Comms("topk", rate=1.0))
    _, s_off, _ = trajectory(ds, model, mk(), None)
    assert max_diff(s_full.params, s_off.params) == 0.0


def test_step_matches_rounds_with_comms(setup):
    """Per-step dispatch and the round executor agree bitwise under comms
    (residual state threads identically)."""
    ds, model = setup
    batch_fn = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8))
    mk = lambda: make_topology("uniform", spec=HierarchySpec((2, 4), (8, 4)))
    e1 = HSGD(model.loss, sgd(0.05), mk(), comms=Comms("topk", rate=0.25))
    s1 = e1.init(jax.random.PRNGKey(0), model.init)
    for t in range(16):
        s1, _ = e1.step(s1, batch_fn(t))
    e2 = HSGD(model.loss, sgd(0.05), mk(), comms=Comms("topk", rate=0.25))
    s2 = e2.init(jax.random.PRNGKey(0), model.init)
    s2, _ = e2.run_rounds(s2, batch_fn, 16)
    assert max_diff(s1.params, s2.params) == 0.0
    assert max_diff(s1.comms, s2.comms) == 0.0


def test_masked_step_with_comms(setup):
    """Runtime participation masks still work through the comms path, and a
    masked worker's error-feedback residual is not consumed (it transmitted
    nothing, even though it receives the aggregate per Algorithm 1)."""
    ds, model = setup
    topo = make_topology("uniform", spec=HierarchySpec((2, 4), (8, 4)))
    mask = np.array([1, 1, 0, 1, 1, 0, 1, 1], bool)
    for comms in (Comms(), Comms("topk", rate=0.1)):
        eng = HSGD(model.loss, sgd(0.05), topo, comms=comms)
        st = eng.init(jax.random.PRNGKey(0), model.init)
        for t in range(8):
            st, m = eng.step(st, jax.tree.map(jnp.asarray, ds.batch(t, 8)),
                             mask=mask)
        assert np.isfinite(float(m["ce"]))
        if comms.codec.stateful:
            for r in jax.tree.leaves(st.comms):
                assert float(jnp.abs(r[~mask]).max()) == 0.0
                assert float(jnp.abs(r[mask]).max()) > 0.0
