"""repro.obs: in-graph probes, metrics bus, trace exporter.

The contracts under test (DESIGN.md "Observability layer"):

* ``metrics=None`` is bitwise-identical to the metrics-free engine — same
  trajectory AND the same state leaf count (no buffer in the pytree);
* the in-graph probe equals the host oracle (`all_divergences`) and
  satisfies the eq. (10) partition identity; sim and mesh lowerings agree;
* the probes audit green: R3 (host-free round body) and R6 (zero extra
  callbacks/transfers, op budget) on the metrics-on configs;
* the metrics bus validates records (kind mismatches always, unknown keys
  under strict) and the trace exporter emits schema-valid Chrome JSON.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.divergence import (all_divergences, divergence_stack,
                                   downward_divergence_avg,
                                   flatten_pytree_batch, global_divergence,
                                   partition_divergences,
                                   partition_divergences_tree,
                                   upward_divergence)
from repro.core.hsgd import HSGD
from repro.core.topology import HierarchySpec, make_topology
from repro.models.simple import SimpleConfig, SimpleModel
from repro.obs import (MetricBuffer, Metrics, MetricSpec, TraceRecorder,
                       make_metrics, register_metric, spec_for,
                       validate_record, validate_trace)
from repro.optim.optimizers import sgd

N = 8
needs_devices = pytest.mark.skipif(
    len(jax.devices()) < N,
    reason=f"mesh tests need {N} devices "
           f"(XLA_FLAGS=--xla_force_host_platform_device_count={N})")

SPEC = HierarchySpec((2, 2, 2), (8, 4, 2))


def tiny_world():
    topo = make_topology("uniform", spec=SPEC)
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=16, hidden=8,
                                     num_classes=4))
    return topo, model


def batch_fn(t):
    x = jax.random.normal(jax.random.PRNGKey(t), (N, 4, 16))
    return {"x": x, "y": jnp.zeros((N, 4), jnp.int32)}


def spread_params(model, scale=0.05, seed=7):
    params = model.init(jax.random.PRNGKey(0))
    return jax.tree.map(
        lambda x: x + scale * jax.random.normal(
            jax.random.PRNGKey(seed), (N,) + x.shape), params)


# -- MetricBuffer ------------------------------------------------------------
def test_buffer_push_wrap_reset():
    buf = MetricBuffer.zeros(3, 2)
    assert buf.capacity == 3 and int(buf.count) == 0
    for i in range(4):  # one past capacity: ring wraps
        buf = buf.push(jnp.full((2,), float(i)))
    assert int(buf.count) == 4
    # slot 0 was overwritten by the 4th push (index 3 % 3 == 0)
    np.testing.assert_allclose(np.asarray(buf.rows)[0], [3.0, 3.0])
    np.testing.assert_allclose(np.asarray(buf.rows)[1], [1.0, 1.0])
    buf = buf.reset()
    assert int(buf.count) == 0 and buf.capacity == 3


def test_make_metrics_resolution():
    assert make_metrics(None) is None
    assert make_metrics(False) is None
    assert isinstance(make_metrics(True), Metrics)
    assert isinstance(make_metrics("on"), Metrics)
    plan = Metrics(grad_norm=False, capacity=7)
    assert make_metrics(plan) is plan
    with pytest.raises(AssertionError):
        make_metrics("sideways")


# -- the probe formulas vs the naive oracle ----------------------------------
def test_partition_divergences_matches_oracle():
    topo, model = tiny_world()
    stacked = spread_params(model)
    x = flatten_pytree_batch(stacked).astype(jnp.float32)
    groupings = topo.level_groupings()
    ordered = [groupings[lvl] for lvl in sorted(groupings)]
    for row in (partition_divergences(x, ordered),
                partition_divergences_tree(stacked, ordered)):
        row = np.asarray(row)
        np.testing.assert_allclose(row[0], float(global_divergence(x)),
                                   rtol=1e-4)
        for i, g in enumerate(ordered):
            np.testing.assert_allclose(row[1 + 2 * i],
                                       float(upward_divergence(x, g)),
                                       rtol=1e-4)
            np.testing.assert_allclose(row[2 + 2 * i],
                                       float(downward_divergence_avg(x, g)),
                                       rtol=1e-4, atol=1e-9)


def test_divergence_stack_matches_all_divergences():
    topo, model = tiny_world()
    x = flatten_pytree_batch(spread_params(model)).astype(jnp.float32)
    g = topo.level_groupings()[1]
    vals = np.asarray(divergence_stack(x, g))
    d = all_divergences(x, g)
    np.testing.assert_allclose(
        vals, [d["global"], d["upward"], d["downward_avg"],
               d["downward_max"]], rtol=1e-6)


def test_probe_row_is_transfer_free():
    topo, model = tiny_world()
    stacked = spread_params(model)
    jaxpr = jax.make_jaxpr(Metrics().sim_row_fn(topo))(stacked)
    assert "device_put" not in str(jaxpr)


# -- live engine probes ------------------------------------------------------
def run_probed(backend="sim", metrics="on", T=8):
    topo, model = tiny_world()
    eng = HSGD(model.loss, sgd(0.1), topo, executor=backend, metrics=metrics)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    st, hist = eng.run_rounds(st, batch_fn, T)
    return eng, st, hist


def test_metrics_off_is_bitwise_identical():
    _, st_off, hist_off = run_probed(metrics=None)
    _, st_on, hist_on = run_probed(metrics="on")
    for a, b in zip(jax.tree.leaves(st_off.params),
                    jax.tree.leaves(st_on.params)):
        assert (np.asarray(a) == np.asarray(b)).all()
    # metrics=None leaves NO extra leaves in the state pytree
    assert st_off.metrics is None
    assert len(jax.tree.leaves(st_off)) + 2 == len(jax.tree.leaves(st_on))
    # ... and no probe keys in history
    assert not any(k.startswith("div_") or k == "grad_norm"
                   for rec in hist_off for k in rec)


def test_probe_history_matches_host_oracle_and_eq10():
    eng, st, hist = run_probed()
    sync = [r for r in hist if "div_global" in r]
    assert [r["t"] for r in sync] == [2, 4, 6, 8]  # every sync event
    for rec in sync:
        for lvl in (1, 2):
            resid = (rec["div_global"] - rec[f"div_up_L{lvl}"]
                     - rec[f"div_down_L{lvl}"])
            assert abs(resid) <= 1e-4 * max(rec["div_global"], 1e-8)
    # every step carries the grad_norm channel
    assert all("grad_norm" in r and r["grad_norm"] > 0 for r in hist)


def test_step_path_pushes_and_drain_metrics():
    topo, model = tiny_world()
    eng = HSGD(model.loss, sgd(0.1), topo, metrics="on")
    st = eng.init(jax.random.PRNGKey(0), model.init)
    for t in range(4):  # two sync events (period 2)
        st, _ = eng.step(st, batch_fn(t))
    assert int(jax.device_get(st.metrics.count)) == 2
    st, rows = eng.drain_metrics(st)
    assert int(jax.device_get(st.metrics.count)) == 0
    assert len(rows) == 2
    keys = set(eng.metrics.history_keys(topo))
    assert all(set(r) == keys for r in rows)
    # drained values are the oracle divergences of the pre-sync params
    # (cheap sanity: non-negative, partition identity)
    for r in rows:
        assert r["div_global"] >= 0
        assert abs(r["div_global"] - r["div_up_L1"] - r["div_down_L1"]) \
            <= 1e-4 * max(r["div_global"], 1e-8)


@needs_devices
def test_sim_mesh_probe_parity():
    _, _, hist_sim = run_probed("sim")
    _, _, hist_mesh = run_probed("mesh")
    sim = [r for r in hist_sim if "div_global" in r]
    mesh = [r for r in hist_mesh if "div_global" in r]
    assert len(sim) == len(mesh) == 4
    for s, m in zip(sim, mesh):
        for k in (k for k in s if k.startswith("div_")):
            assert abs(s[k] - m[k]) <= 1e-4 * max(abs(s[k]), 1e-8), (k, s, m)


@needs_devices
def test_mesh_metrics_off_is_bitwise_identical():
    _, st_off, _ = run_probed("mesh", metrics=None)
    _, st_on, _ = run_probed("mesh", metrics="on")
    for a, b in zip(jax.tree.leaves(st_off.params),
                    jax.tree.leaves(st_on.params)):
        assert (np.asarray(a) == np.asarray(b)).all()


# -- the R3/R6 audit contract ------------------------------------------------
def test_probes_audit_r3_r6_green():
    topo, model = tiny_world()
    eng = HSGD(model.loss, sgd(0.1), topo, metrics="on")
    st = eng.init(jax.random.PRNGKey(0), model.init)
    report = eng.audit(st, batch_fn=batch_fn, run=False)
    assert report.probes is not None
    assert not [f for f in report.findings if f.rule in ("R3", "R6")], \
        report.findings
    budget = report.probes["budget"]
    for key, d in report.probes["rounds"].items():
        assert d["extra_callbacks"] == 0 and d["extra_transfers"] == 0, key
        assert 0 < d["extra_ops"] <= budget, (key, d, budget)


def test_op_budget_shape():
    topo, _ = tiny_world()
    m = Metrics()
    assert m.op_budget("mesh", topo, 4) == (2 + 2) + 1  # L+2 + grad_norm
    assert m.op_budget("sim", topo, 4) == 3 * 4 * 3 + 5
    off = Metrics(divergences=False, grad_norm=False)
    assert off.op_budget("sim", topo, 4) == 0


# -- metrics bus -------------------------------------------------------------
def test_bus_registry_and_validation():
    assert spec_for("div_up_L3").kind == "scalar"  # fnmatch family
    assert spec_for("sim_sync_s").kind == "mapping"
    assert spec_for("no_such_channel") is None
    ok = {"t": 3, "ce": 1.25, "div_global": 0.1, "grad_norm": 2.0,
          "wire_bytes": 128, "sim_sync_s": {"L1": 0.2}}
    assert validate_record(ok) == []
    assert validate_record(ok, strict=True) == []
    bad = {"t": 1.5, "sim_sync_s": 3.0, "dropped": True}
    errs = validate_record(bad)
    assert len(errs) == 3
    # unknown keys: lenient passes, strict complains
    assert validate_record({"my_custom": 1.0}) == []
    assert validate_record({"my_custom": 1.0}, strict=True)
    with pytest.raises(ValueError):
        register_metric(MetricSpec("t"))  # duplicate without overwrite


# -- trace exporter ----------------------------------------------------------
def test_trace_export_schema():
    rec = TraceRecorder()
    rec.compute_span(0, 0.0, 1.0)
    rec.wait_span(0, 2, 1.0, 0.5)
    rec.sync_span(2, 1.5, 0.25, payload_bytes=1024, dropped=1)
    rec.divergences(4, 2, 1.75, {"global": 0.5, "up_L1": 0.2})
    obj = rec.to_json()
    assert validate_trace(rec) == []
    assert validate_trace(obj) == []
    assert obj["otherData"]["exporter"] == "repro.obs"
    phases = {e["ph"] for e in obj["traceEvents"]}
    assert {"X", "C", "i", "M"} <= phases


def test_trace_validation_catches_malformed():
    assert validate_trace([1, 2, 3])
    assert validate_trace({"events": []})
    bad_phase = {"traceEvents": [
        {"name": "x", "ph": "Q", "pid": 0, "tid": 0, "ts": 0}]}
    assert any("phase" in e for e in validate_trace(bad_phase))
    neg = {"traceEvents": [
        {"name": "x", "ph": "X", "pid": 0, "tid": 0, "ts": -1, "dur": 1}]}
    assert any("ts" in e for e in validate_trace(neg))
    no_dur = {"traceEvents": [
        {"name": "x", "ph": "X", "pid": 0, "tid": 0, "ts": 0}]}
    assert any("dur" in e for e in validate_trace(no_dur))


def test_run_rounds_trace_fallback_spans():
    topo, model = tiny_world()
    eng = HSGD(model.loss, sgd(0.1), topo, comms="identity", metrics="on")
    st = eng.init(jax.random.PRNGKey(0), model.init)
    rec = TraceRecorder()
    st, hist = eng.run_rounds(st, batch_fn, 8, trace=rec)
    assert validate_trace(rec) == []
    names = [e["name"] for e in rec.events]
    assert any(n.startswith("round") for n in names)   # step-index spans
    assert any(n.startswith("sync L") for n in names)
    assert any(e["ph"] == "C" for e in rec.events)     # divergence counters
    syncs = [e for e in rec.events
             if e["ph"] == "X" and e["name"].startswith("sync L")]
    assert all(e["args"]["payload_bytes"] > 0 for e in syncs)
