"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ref import attention_ref, rglru_ref, ssd_ref


@pytest.mark.parametrize("b,s,hq,hk,d,blk,causal,window", [
    (2, 64, 4, 2, 32, 16, True, None),
    (1, 48, 2, 1, 16, 16, True, 8),       # padded seq + sliding window
    (2, 32, 4, 4, 32, 32, False, None),   # bidirectional (encoder)
    (1, 128, 8, 2, 64, 32, True, None),
    (1, 40, 3, 1, 8, 16, True, 4),        # odd heads, non-divisible seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, hq, hk, d, blk, causal, window, dtype, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, hk, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, hk, d)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=blk, block_k=blk, interpret=True)
    kr = jnp.repeat(k, hq // hk, axis=2)
    vr = jnp.repeat(v, hq // hk, axis=2)
    ref = attention_ref(q.astype(jnp.float32), kr.astype(jnp.float32),
                        vr.astype(jnp.float32), causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("bt,s,h,p,n,chunk", [
    (2, 32, 4, 8, 16, 8),
    (1, 40, 2, 16, 8, 16),   # padded
    (2, 64, 3, 8, 4, 64),    # single chunk
    (1, 16, 1, 4, 4, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(bt, s, h, p, n, chunk, dtype, rng):
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (bt, s, h, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, s, h))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (bt, s, n)).astype(dtype)
    C = jax.random.normal(ks[4], (bt, s, n)).astype(dtype)
    out = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    ref, _ = ssd_ref(x.astype(jnp.float32), dt, A, B.astype(jnp.float32),
                     C.astype(jnp.float32))
    scale = float(jnp.abs(ref).max()) + 1e-9
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    assert float(jnp.abs(out.astype(jnp.float32) - ref).max()) / scale < tol


@pytest.mark.parametrize("bt,s,w,block", [
    (2, 32, 8, 8),
    (1, 50, 16, 16),   # padded
    (2, 64, 4, 64),
    (1, 8, 2, 4),
])
def test_rglru_scan_sweep(bt, s, w, block, rng):
    ks = jax.random.split(rng, 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (bt, s, w))) * 0.2 + 0.79
    b = jax.random.normal(ks[1], (bt, s, w))
    out = rglru_scan(a, b, block=block, interpret=True)
    ref, _ = rglru_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-5, rtol=1e-4)


def test_flash_attention_matches_model_layer(rng):
    """End-to-end: pallas-routed attention layer == jnp layer."""
    import dataclasses
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("gemma3-12b"))
    cfgp = dataclasses.replace(cfg, use_pallas=True)
    m0, m1 = build_model(cfg), build_model(cfgp)
    params = m0.init(rng)
    toks = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
    l0, _ = m0.forward(params, toks)
    l1, _ = m1.forward(params, toks)
    assert float(jnp.abs(l0 - l1).max()) < 5e-4
