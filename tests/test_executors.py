"""Plan/executor split: the SimExecutor extraction, the MeshExecutor's
named-axis lowering, and sim<->mesh trajectory equivalence.

The in-process mesh tests need >= 8 devices; ci.yml provides them via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before jax
initializes).  Without them they skip, and the subprocess test at the bottom
still covers the equivalence suite on a plain single-device run."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (HSGD, Executor, GroupedTopology, Grouping,
                        HierarchySpec, MeshExecutor, Round, SimExecutor,
                        SyncEvent, WeightedAggregator, contiguous,
                        make_executor, make_topology)
from repro.data import FederatedDataset, label_shard_partition, make_classification
from repro.models import SimpleConfig, SimpleModel
from repro.optim import sgd

N = 8
needs_devices = pytest.mark.skipif(
    len(jax.devices()) < N,
    reason="needs 8 devices: export XLA_FLAGS="
           "--xla_force_host_platform_device_count=8 before jax init")

SPECS = {
    "two_level": (HierarchySpec((2, 4), (8, 4)), (2, 4)),
    "three_level": (HierarchySpec((2, 2, 2), (8, 4, 2)), (2, 2, 2)),
}


@pytest.fixture(scope="module")
def setup():
    x, y = make_classification(0, num_classes=8, dim=16, per_class=40)
    parts = label_shard_partition(y, [[j] for j in range(8)])
    ds = FederatedDataset(x, y, parts)
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=16, hidden=24,
                                     num_classes=8))
    return ds, model


def max_param_diff(a, b):
    d = jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), a, b)
    return max(jax.tree.leaves(d))


def trajectory(ds, model, topo, executor, T=12):
    eng = HSGD(model.loss, sgd(0.05), topo, executor=executor)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    st, hist = eng.run_rounds(
        st, lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8)), T)
    return st, hist


# ---------------------------------------------------------------------------
# registry / validation (device-count independent)
# ---------------------------------------------------------------------------
def test_make_executor_registry():
    assert isinstance(make_executor(None), SimExecutor)
    assert isinstance(make_executor("sim"), SimExecutor)
    assert isinstance(make_executor("mesh"), MeshExecutor)
    inst = SimExecutor()
    assert make_executor(inst) is inst
    with pytest.raises(KeyError):
        make_executor("tpu_pod")


def test_hsgd_accepts_executor_spellings(setup):
    ds, model = setup
    topo = make_topology("two_level", n=N, N=2, G=8, I=4)
    eng = HSGD(model.loss, sgd(0.05), topo, executor="sim")
    assert isinstance(eng.executor, SimExecutor)
    assert eng.executor.plan is eng


@needs_devices
def test_mesh_accepts_grouped_topology(setup):
    """GroupedTopology runs on the mesh backend (flat worker-axis lowering);
    the auto-built mesh is the (n,)-replica one."""
    ds, model = setup
    topo = GroupedTopology(contiguous(N, 2), G=8, I=4)
    eng = HSGD(model.loss, sgd(0.05), topo, executor="mesh")
    assert tuple(eng.executor.mesh.shape[a]
                 for a in eng.executor.rep_axes) == (N,)


@needs_devices
def test_mesh_accepts_elastic_runtime_at_construction(setup):
    """An elastic policy becomes runtime masks, which the mesh backend now
    lowers as per-worker collective weights — construction succeeds (it
    used to raise NotImplementedError naming the sim fallback)."""
    from repro.runtime import RuntimeModel
    ds, model = setup
    mk = lambda: make_topology("two_level", n=N, N=2, G=8, I=4)
    eng = HSGD(model.loss, sgd(0.05), mk(), executor="mesh",
               runtime=RuntimeModel(compute_s=1.0, policy=2.0))
    assert eng.runtime is not None and eng.runtime.elastic


def test_level_axes_mapping():
    topo = make_topology("uniform", spec=HierarchySpec((2, 2, 2), (8, 4, 2)))
    axes = ("pod", "rack", "data")
    assert topo.level_axes(SyncEvent(level=1), axes) == ("pod", "rack", "data")
    assert topo.level_axes(SyncEvent(level=2), axes) == ("rack", "data")
    assert topo.level_axes(SyncEvent(level=3), axes) == ("data",)
    with pytest.raises(AssertionError):
        topo.level_axes(SyncEvent(level=1), ("pod", "data"))  # wrong depth
    # grouped topologies lower every event over the FLAT worker axis (the
    # membership rides as one-hot weights in shard_aggregate)
    grouped = GroupedTopology(contiguous(N, 2), G=8, I=4)
    assert grouped.level_axes(SyncEvent(level=1), ("data",)) == ("data",)
    assert grouped.level_axes(
        SyncEvent(level=2, groups=(True, False)), ("data",)) == ("data",)


def test_level_groupings_derivation():
    topo = make_topology("uniform", spec=HierarchySpec((2, 2, 2), (8, 4, 2)))
    gs = topo.level_groupings()
    assert sorted(gs) == [1, 2]
    assert gs[1].assignment == contiguous(8, 2).assignment
    assert gs[2].assignment == contiguous(8, 4).assignment
    g = contiguous(N, 2)
    assert GroupedTopology(g, G=8, I=4).level_groupings() == {1: g}
    assert make_topology("local_sgd", n=N, P=4).level_groupings() == {}


# ---------------------------------------------------------------------------
# sim <-> mesh trajectory equivalence (8 host devices)
# ---------------------------------------------------------------------------
@needs_devices
@pytest.mark.parametrize("agg", [None, "compressed", "sign"],
                         ids=["mean", "compressed", "sign"])
@pytest.mark.parametrize("spec_name", sorted(SPECS))
def test_mesh_pmean_matches_sim(setup, spec_name, agg):
    """The production lowering (pmean over the level axes) must reproduce
    the sim trajectory to f32 rounding."""
    from repro.launch.mesh import make_host_mesh
    ds, model = setup
    spec, gs = SPECS[spec_name]
    mk = lambda: make_topology("uniform", spec=spec, aggregator=agg)
    st_sim, h_sim = trajectory(ds, model, mk(), "sim")
    st_mesh, h_mesh = trajectory(
        ds, model, mk(), MeshExecutor(make_host_mesh(group_sizes=gs)))
    assert max_param_diff(st_sim.params, st_mesh.params) < 5e-6
    assert [r["t"] for r in h_mesh] == [r["t"] for r in h_sim]
    for a, b in zip(h_sim, h_mesh):
        assert abs(a["ce"] - b["ce"]) < 1e-5


@needs_devices
@pytest.mark.parametrize("agg", [None, "compressed", "sign"],
                         ids=["mean", "compressed", "sign"])
@pytest.mark.parametrize("spec_name", sorted(SPECS))
def test_mesh_exact_is_bitwise(setup, spec_name, agg):
    """exact=True replays the sim reshape-mean per shard: trajectories are
    bit-identical for the plain-mean rules."""
    from repro.launch.mesh import make_host_mesh
    ds, model = setup
    spec, gs = SPECS[spec_name]
    mk = lambda: make_topology("uniform", spec=spec, aggregator=agg)
    st_sim, _ = trajectory(ds, model, mk(), "sim")
    st_mesh, _ = trajectory(
        ds, model, mk(),
        MeshExecutor(make_host_mesh(group_sizes=gs), exact=True))
    assert max_param_diff(st_sim.params, st_mesh.params) == 0.0


@needs_devices
def test_mesh_weighted_aggregator(setup):
    """Static per-worker weights ride the named-axis lowering (psum of
    weighted payloads / psum of weights) to f32 rounding."""
    from repro.launch.mesh import make_host_mesh
    ds, model = setup
    spec, gs = SPECS["two_level"]
    w = np.arange(1, N + 1, dtype=float)
    mk = lambda: make_topology("uniform", spec=spec,
                               aggregator=WeightedAggregator(w))
    st_sim, _ = trajectory(ds, model, mk(), "sim")
    st_mesh, _ = trajectory(
        ds, model, mk(), MeshExecutor(make_host_mesh(group_sizes=gs)))
    assert max_param_diff(st_sim.params, st_mesh.params) < 5e-6


@needs_devices
def test_mesh_step_matches_rounds(setup):
    """Per-step dispatch and the round executor agree bitwise on mesh too."""
    from repro.launch.mesh import make_host_mesh
    ds, model = setup
    spec, gs = SPECS["two_level"]
    batch_fn = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8))
    mk = lambda: make_topology("uniform", spec=spec)
    ex = lambda: MeshExecutor(make_host_mesh(group_sizes=gs))
    e1 = HSGD(model.loss, sgd(0.05), mk(), executor=ex())
    s1 = e1.init(jax.random.PRNGKey(0), model.init)
    for t in range(10):
        s1, _ = e1.step(s1, batch_fn(t))
    e2 = HSGD(model.loss, sgd(0.05), mk(), executor=ex())
    s2 = e2.init(jax.random.PRNGKey(0), model.init)
    s2, _ = e2.run_rounds(s2, batch_fn, 10)
    assert max_param_diff(s1.params, s2.params) == 0.0
    assert int(s2.step) == 10


@needs_devices
def test_mesh_rejects_mismatched_mesh(setup):
    from repro.launch.mesh import make_host_mesh
    ds, model = setup
    spec, gs = SPECS["two_level"]
    # a flat 8-replica mesh does not mirror the 2-level hierarchy
    flat = make_host_mesh(n_data=8)
    with pytest.raises((AssertionError, ValueError)):
        HSGD(model.loss, sgd(0.05), make_topology("uniform", spec=spec),
             executor=MeshExecutor(flat))
    # a grouped topology needs n_replicas(mesh) == n workers
    with pytest.raises(ValueError, match="worker"):
        HSGD(model.loss, sgd(0.05), GroupedTopology(contiguous(4, 2), G=8,
                                                    I=4),
             executor=MeshExecutor(make_host_mesh(n_data=8)))


@needs_devices
def test_mesh_exact_weighted_gather(setup):
    """Exact mode for the WEIGHTED rule: the all-gather + replayed
    ``topology.aggregate`` recomputes the sim weight combination, but the
    fused multiply+reduce may still reassociate under a different program
    context, so we assert f32-rounding agreement (bitwise is asserted for
    mean/compressed/sign and the grouped/masked paths)."""
    from repro.launch.mesh import make_host_mesh
    ds, model = setup
    spec, gs = SPECS["two_level"]
    w = np.arange(1, N + 1, dtype=float)
    mk = lambda: make_topology("uniform", spec=spec,
                               aggregator=WeightedAggregator(w))
    st_sim, _ = trajectory(ds, model, mk(), "sim")
    st_mesh, _ = trajectory(
        ds, model, mk(),
        MeshExecutor(make_host_mesh(group_sizes=gs), exact=True))
    assert max_param_diff(st_sim.params, st_mesh.params) < 5e-6


# ---------------------------------------------------------------------------
# comms (FlatBucket + codecs) across executors
# ---------------------------------------------------------------------------
@needs_devices
@pytest.mark.parametrize("spec_name", sorted(SPECS))
def test_mesh_comms_identity_pmean(setup, spec_name):
    """FlatBucket + identity codec through the production pmean lowering:
    sim and mesh agree to f32 rounding, as without comms."""
    from repro.comms import Comms
    from repro.launch.mesh import make_host_mesh
    ds, model = setup
    spec, gs = SPECS[spec_name]
    mk = lambda: make_topology("uniform", spec=spec)
    e = lambda ex: HSGD(model.loss, sgd(0.05), mk(), executor=ex,
                        comms=Comms())
    bf = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8))
    e1 = e("sim")
    s1 = e1.init(jax.random.PRNGKey(0), model.init)
    s1, h1 = e1.run_rounds(s1, bf, 12)
    e2 = e(MeshExecutor(make_host_mesh(group_sizes=gs)))
    s2 = e2.init(jax.random.PRNGKey(0), model.init)
    s2, h2 = e2.run_rounds(s2, bf, 12)
    assert max_param_diff(s1.params, s2.params) < 5e-6
    assert [r["wire_bytes"] for r in h1] == [r["wire_bytes"] for r in h2]


@needs_devices
@pytest.mark.parametrize("comms", ["identity", "int8", "topk"])
@pytest.mark.parametrize("spec_name", sorted(SPECS))
def test_mesh_comms_exact_is_bitwise(setup, spec_name, comms):
    """exact mode replays the sim bucket reduce per shard: bit-identical
    trajectories AND bit-identical error-feedback residuals."""
    from repro.comms import Comms
    from repro.launch.mesh import make_host_mesh
    ds, model = setup
    spec, gs = SPECS[spec_name]
    mk = lambda: make_topology("uniform", spec=spec)
    mkc = lambda: Comms("topk", rate=0.25) if comms == "topk" else \
        Comms(comms)
    bf = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8))
    e1 = HSGD(model.loss, sgd(0.05), mk(), comms=mkc())
    s1 = e1.init(jax.random.PRNGKey(0), model.init)
    s1, _ = e1.run_rounds(s1, bf, 12)
    e2 = HSGD(model.loss, sgd(0.05), mk(), comms=mkc(),
              executor=MeshExecutor(make_host_mesh(group_sizes=gs),
                                    exact=True))
    s2 = e2.init(jax.random.PRNGKey(0), model.init)
    s2, _ = e2.run_rounds(s2, bf, 12)
    assert max_param_diff(s1.params, s2.params) == 0.0
    if comms == "topk":
        assert max_param_diff(s1.comms, s2.comms) == 0.0


@needs_devices
def test_mesh_comms_fuses_collectives(setup):
    """The lowered mesh round syncs O(dtypes) fused buffers, not O(leaves)
    arrays: the collective count drops to 1 bucket + 1 metrics pmean (the
    no-regression check is a jaxpr walk via repro.analysis, not wall-clock
    and not substring counting)."""
    from repro.analysis import walk
    from repro.comms import Comms
    from repro.core.hsgd import Round
    from repro.launch.mesh import make_host_mesh
    ds, model = setup
    spec, gs = SPECS["two_level"]
    bf = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8))
    batches = tuple(bf(t) for t in range(4))
    counts = {}
    for comms in (None, Comms()):
        eng = HSGD(model.loss, sgd(0.05),
                   make_topology("uniform", spec=spec), comms=comms,
                   executor=MeshExecutor(make_host_mesh(group_sizes=gs)))
        st = eng.init(jax.random.PRNGKey(0), model.init)
        rnd = Round(4, SyncEvent(level=1))
        summary = walk(eng.executor.round_jaxpr(rnd, st, batches))
        counts[comms is None] = summary.collective_count
    n_leaves = len(jax.tree.leaves(model.init(jax.random.PRNGKey(0))))
    assert counts[True] == n_leaves + 1   # leaf-wise syncs + metrics pmean
    assert counts[False] == 1 + 1         # one f32 bucket + metrics pmean


# ---------------------------------------------------------------------------
# grouped topologies on the mesh (flat worker-axis lowering)
# ---------------------------------------------------------------------------
GROUPED = {
    "uniform_I": lambda **kw: GroupedTopology(contiguous(N, 2), G=8, I=4,
                                              **kw),
    "hetero_I": lambda **kw: GroupedTopology(contiguous(N, 2), G=8,
                                             I=(2, 4), **kw),
    # non-uniform group sizes (Theorem 1's general setting)
    "nonuniform": lambda **kw: GroupedTopology(
        Grouping((0, 0, 0, 0, 0, 1, 1, 1)), G=8, I=(2, 4), **kw),
}


@needs_devices
@pytest.mark.parametrize("agg", [None, "sign"], ids=["mean", "sign"])
@pytest.mark.parametrize("name", sorted(GROUPED))
def test_mesh_grouped_matches_sim(setup, name, agg):
    """GroupedTopology through the production one-hot-psum lowering matches
    sim to f32 rounding — including heterogeneous per-group periods, whose
    partial SyncEvent(level=2, groups=...) events used to be rejected."""
    from repro.launch.mesh import make_host_mesh
    ds, model = setup
    mk = lambda: GROUPED[name](aggregator=agg)
    st_sim, h_sim = trajectory(ds, model, mk(), "sim", T=16)
    st_mesh, h_mesh = trajectory(
        ds, model, mk(), MeshExecutor(make_host_mesh(group_sizes=(N,))),
        T=16)
    assert max_param_diff(st_sim.params, st_mesh.params) < 5e-6
    for a, b in zip(h_sim, h_mesh):
        assert abs(a["ce"] - b["ce"]) < 1e-5


@needs_devices
@pytest.mark.parametrize("comms", [None, "int8"], ids=["plain", "int8"])
@pytest.mark.parametrize("name", sorted(GROUPED))
def test_mesh_grouped_exact_is_bitwise(setup, name, comms):
    """exact=True replays the sim segment-mean (and the comms bucket
    reduce) on the all-gathered worker block: grouped mesh trajectories are
    bit-identical to sim, partial-group events included."""
    from repro.comms import Comms
    from repro.launch.mesh import make_host_mesh
    ds, model = setup
    mkc = lambda: None if comms is None else Comms(comms)
    bf = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8))
    e1 = HSGD(model.loss, sgd(0.05), GROUPED[name](), comms=mkc())
    s1 = e1.init(jax.random.PRNGKey(0), model.init)
    s1, _ = e1.run_rounds(s1, bf, 16)
    e2 = HSGD(model.loss, sgd(0.05), GROUPED[name](), comms=mkc(),
              executor=MeshExecutor(make_host_mesh(group_sizes=(N,)),
                                    exact=True))
    s2 = e2.init(jax.random.PRNGKey(0), model.init)
    s2, _ = e2.run_rounds(s2, bf, 16)
    assert max_param_diff(s1.params, s2.params) == 0.0


# ---------------------------------------------------------------------------
# masked rounds (runtime participation) on the mesh
# ---------------------------------------------------------------------------
MASK = np.array([1, 1, 0, 1, 1, 0, 1, 1], bool)


def _masked_round_state(ds, model, executor, comms=None):
    """Two warm-up rounds (residual build-up), then one elastic-drop round."""
    eng = HSGD(model.loss, sgd(0.05),
               make_topology("uniform", spec=HierarchySpec((2, 4), (4, 4))),
               executor=executor, comms=comms)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    bf = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8))
    st, _ = eng.run_rounds(st, bf, 8)
    batches = tuple(bf(t) for t in range(8, 12))
    st, _ = eng.round_fn(Round(4, SyncEvent(level=1)), masked=True)(
        st, batches, jnp.asarray(MASK))
    return jax.device_get(st)


@needs_devices
def test_mesh_masked_round_matches_sim(setup):
    from repro.launch.mesh import make_host_mesh
    ds, model = setup
    a = _masked_round_state(ds, model, "sim")
    b = _masked_round_state(
        ds, model, MeshExecutor(make_host_mesh(group_sizes=(2, 4))))
    assert max_param_diff(a.params, b.params) < 5e-6


@needs_devices
def test_mesh_masked_round_exact_bitwise_with_residuals(setup):
    """THE elastic-participation contract on the mesh, bitwise: a dropped
    worker keeps its exact post-update params, opt state AND unconsumed
    topk error-feedback residual; admitted workers' aggregates (and
    consumed residuals) replay the sim reduce bit-for-bit."""
    from repro.comms import Comms
    from repro.launch.mesh import make_host_mesh
    ds, model = setup
    mkc = lambda: Comms("topk", rate=0.25)
    a = _masked_round_state(ds, model, "sim", comms=mkc())
    b = _masked_round_state(
        ds, model, MeshExecutor(make_host_mesh(group_sizes=(2, 4)),
                                exact=True), comms=mkc())
    assert max_param_diff(a.params, b.params) == 0.0
    assert max_param_diff(a.opt_state, b.opt_state) == 0.0
    assert max_param_diff(a.comms, b.comms) == 0.0
    # and the drop contract itself holds on the mesh result: dropped rows
    # carry a residual a synced worker's round would have consumed
    res_a, res_b = jax.tree.leaves(a.comms), jax.tree.leaves(b.comms)
    for ra, rb in zip(res_a, res_b):
        np.testing.assert_array_equal(np.asarray(ra)[~MASK],
                                      np.asarray(rb)[~MASK])


@needs_devices
def test_mesh_masked_step_matches_sim(setup):
    """Algorithm-1 mask semantics (HSGD.step(..., mask=...)): masked-out
    workers contribute nothing but still receive the aggregate — now lowered
    by the mesh backend too, bitwise in exact mode."""
    from repro.launch.mesh import make_host_mesh
    ds, model = setup
    spec, gs = SPECS["two_level"]
    bf = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8))
    mask = np.array([1, 0, 1, 1, 1, 1, 0, 1], bool)

    def drive(executor):
        eng = HSGD(model.loss, sgd(0.05),
                   make_topology("uniform", spec=spec), executor=executor)
        st = eng.init(jax.random.PRNGKey(0), model.init)
        for t in range(4):
            st, _ = eng.step(st, bf(t), mask=mask)
        return jax.device_get(st)

    a = drive("sim")
    b = drive(MeshExecutor(make_host_mesh(group_sizes=gs), exact=True))
    c = drive(MeshExecutor(make_host_mesh(group_sizes=gs)))
    assert max_param_diff(a.params, b.params) == 0.0
    assert max_param_diff(a.params, c.params) < 5e-6


@needs_devices
def test_mesh_elastic_runtime_end_to_end(setup):
    """run_rounds with stragglers + a deadline policy on the mesh backend:
    the host-side clock hands both executors identical masks, so the exact
    mesh trajectory (params AND residuals) is bitwise the sim one, and the
    simulated accounting is backend-independent."""
    from repro.comms import Comms
    from repro.launch.mesh import make_host_mesh
    from repro.runtime import RuntimeModel
    ds, model = setup
    spec, gs = SPECS["two_level"]
    bf = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8))

    def run(executor):
        rt = RuntimeModel(compute_s=1.0, straggler="fixed:0.25:6",
                          policy=1.0, seed=11)
        eng = HSGD(model.loss, sgd(0.05),
                   make_topology("uniform", spec=spec), executor=executor,
                   runtime=rt, comms=Comms("topk", rate=0.5))
        st = eng.init(jax.random.PRNGKey(0), model.init)
        st, hist = eng.run_rounds(st, bf, 16)
        return eng, jax.device_get(st), hist

    eng_s, st_s, h_s = run("sim")
    eng_m, st_m, h_m = run(MeshExecutor(make_host_mesh(group_sizes=gs),
                                        exact=True))
    assert eng_m.runtime_report()["dropped"][2] > 0
    assert max_param_diff(st_s.params, st_m.params) == 0.0
    assert max_param_diff(st_s.comms, st_m.comms) == 0.0
    assert [r["sim_time_s"] for r in h_s] == [r["sim_time_s"] for r in h_m]


@needs_devices
def test_mesh_grouped_elastic_runtime_end_to_end(setup):
    """Theorem-2-style grouped schedules + deadline drops compose on the
    mesh: partial-group events and runtime masks in one run, bitwise vs sim
    in exact mode."""
    from repro.launch.mesh import make_host_mesh
    from repro.runtime import RuntimeModel
    ds, model = setup
    bf = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8))
    mk = lambda: GroupedTopology(contiguous(N, 2), G=8, I=(2, 4))
    rt = lambda: RuntimeModel(compute_s=1.0, straggler="lognormal:0.9",
                              policy=0.25, seed=4)

    def run(executor):
        eng = HSGD(model.loss, sgd(0.05), mk(), executor=executor,
                   runtime=rt())
        st = eng.init(jax.random.PRNGKey(0), model.init)
        st, hist = eng.run_rounds(st, bf, 16)
        return eng, jax.device_get(st), hist

    eng_s, st_s, h_s = run("sim")
    eng_m, st_m, h_m = run(MeshExecutor(make_host_mesh(group_sizes=(N,)),
                                        exact=True))
    assert sum(eng_m.runtime_report()["dropped"].values()) > 0
    assert max_param_diff(st_s.params, st_m.params) == 0.0
    # the ce METRIC reduces in a different order on mesh (per-shard mean +
    # pmean), so it matches to rounding, not bitwise
    assert all(abs(a["ce"] - b["ce"]) < 1e-5 for a, b in zip(h_s, h_m))


# ---------------------------------------------------------------------------
# subprocess: the equivalence suite on a forced 8-device host platform, so
# plain single-device `pytest -q` runs still exercise the mesh backend
# ---------------------------------------------------------------------------
_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.comms import Comms
from repro.core import HSGD, HierarchySpec, MeshExecutor, make_topology
from repro.data import FederatedDataset, label_shard_partition, make_classification
from repro.models import SimpleConfig, SimpleModel
from repro.optim import sgd
from repro.launch.mesh import make_host_mesh

x, y = make_classification(0, num_classes=8, dim=16, per_class=40)
parts = label_shard_partition(y, [[j] for j in range(8)])
ds = FederatedDataset(x, y, parts)
model = SimpleModel(SimpleConfig(kind="mlp", input_dim=16, hidden=24,
                                 num_classes=8))
batch_fn = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8))

def diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda p, q: float(jnp.abs(p - q).max()), a, b)))

def run(topo, executor, comms=None):
    eng = HSGD(model.loss, sgd(0.05), topo, executor=executor, comms=comms)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    st, _ = eng.run_rounds(st, batch_fn, 10)
    return st

for gs, periods in [((2, 4), (8, 4)), ((2, 2, 2), (8, 4, 2))]:
    spec = HierarchySpec(gs, periods)
    mk = lambda: make_topology("uniform", spec=spec)
    s_sim = run(mk(), "sim")
    s_pmean = run(mk(), MeshExecutor(make_host_mesh(group_sizes=gs)))
    s_exact = run(mk(), MeshExecutor(make_host_mesh(group_sizes=gs),
                                     exact=True))
    d_pmean = diff(s_sim.params, s_pmean.params)
    d_exact = diff(s_sim.params, s_exact.params)
    assert d_pmean < 5e-6, (gs, d_pmean)
    assert d_exact == 0.0, (gs, d_exact)
    # comms: FlatBucket + int8 codec, exact lowering replays the sim bucket
    # reduce per shard -> bitwise
    s_csim = run(mk(), "sim", comms=Comms("int8"))
    s_cexact = run(mk(), MeshExecutor(make_host_mesh(group_sizes=gs),
                                      exact=True), comms=Comms("int8"))
    d_comms = diff(s_csim.params, s_cexact.params)
    assert d_comms == 0.0, (gs, d_comms)

# grouped topology (flat worker-axis lowering, partial level-2 events) and
# deadline-elastic drops: mesh parity for the scenarios that used to be
# rejected at construction
from repro.core import GroupedTopology, contiguous
from repro.runtime import RuntimeModel

mkg = lambda: GroupedTopology(contiguous(8, 2), G=8, I=(2, 4))
s_gsim = run(mkg(), "sim")
s_gpm = run(mkg(), MeshExecutor(make_host_mesh(group_sizes=(8,))))
s_gex = run(mkg(), MeshExecutor(make_host_mesh(group_sizes=(8,)),
                                exact=True))
assert diff(s_gsim.params, s_gpm.params) < 5e-6
assert diff(s_gsim.params, s_gex.params) == 0.0

def run_elastic(executor):
    rt = RuntimeModel(compute_s=1.0, straggler="fixed:0.25:6", policy=1.0,
                      seed=11)
    eng = HSGD(model.loss, sgd(0.05),
               make_topology("uniform", spec=HierarchySpec((2, 4), (8, 2))),
               executor=executor, runtime=rt)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    st, _ = eng.run_rounds(st, batch_fn, 16)
    assert sum(eng.runtime_report()["dropped"].values()) > 0
    return st

s_esim = run_elastic("sim")
s_eex = run_elastic(MeshExecutor(make_host_mesh(group_sizes=(2, 4)),
                                 exact=True))
assert diff(s_esim.params, s_eex.params) == 0.0
print("MESH_EQUIV_OK")
"""


@pytest.mark.slow
def test_mesh_equivalence_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MESH_EQUIV_OK" in r.stdout
