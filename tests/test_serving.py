"""Serving engine tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving import DecodeEngine


def test_greedy_matches_forward_argmax(rng):
    cfg = reduced(get_config("qwen2-0.5b"))
    m = build_model(cfg)
    params = m.init(rng)
    prompt = jax.random.randint(rng, (2, 6), 0, cfg.vocab_size)
    eng = DecodeEngine(m, params)
    res = eng.generate(prompt, 4)
    assert res.tokens.shape == (2, 4)
    # greedy decode step-by-step against teacher-forced full forwards
    seq = np.asarray(prompt)
    for t in range(4):
        logits, _ = m.forward(params, jnp.asarray(seq))
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
        np.testing.assert_array_equal(res.tokens[:, t], nxt)
        seq = np.concatenate([seq, nxt[:, None]], axis=1)


def test_score_continuation(rng):
    cfg = reduced(get_config("mamba2-130m"))
    m = build_model(cfg)
    params = m.init(rng)
    prompt = jax.random.randint(rng, (2, 5), 0, cfg.vocab_size)
    cont = jax.random.randint(jax.random.fold_in(rng, 1), (2, 3),
                              0, cfg.vocab_size)
    eng = DecodeEngine(m, params)
    total = eng.score_continuation(prompt, cont)
    # reference: teacher-forced full forward
    seq = jnp.concatenate([prompt, cont], axis=1)
    logits, _ = m.forward(params, seq)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    ref = np.zeros(2)
    for t in range(3):
        ref += np.asarray(jnp.take_along_axis(
            logp[:, 4 + t], cont[:, t][:, None], axis=-1))[:, 0]
    np.testing.assert_allclose(total, ref, atol=1e-3)


def test_encdec_generation(rng):
    cfg = reduced(get_config("seamless-m4t-large-v2"))
    m = build_model(cfg)
    params = m.init(rng)
    prompt = jax.random.randint(rng, (2, 4), 0, cfg.vocab_size)
    enc = jax.random.normal(rng, (2, 4, cfg.d_model), dtype=jnp.float32)
    eng = DecodeEngine(m, params)
    res = eng.generate(prompt, 3, enc_inputs=enc)
    assert res.tokens.shape == (2, 3)
    assert np.isfinite(res.logprobs).all()
