"""The unified topology/aggregation surface: SyncEvent schedules, pluggable
Aggregator strategies through both topologies, the make_topology registry,
and the schedule-compiled round executor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (HSGD, CompressedAggregator, GroupedTopology, Grouping,
                        HierarchySpec, MeanAggregator, Round, SignSGDAggregator,
                        SyncEvent, UniformTopology, WeightedAggregator,
                        compile_schedule, contiguous, local_sgd, make_aggregator,
                        make_topology, run, two_level)
from repro.data import FederatedDataset, label_shard_partition, make_classification
from repro.models import SimpleConfig, SimpleModel
from repro.optim import sgd

N = 8


@pytest.fixture(scope="module")
def setup():
    x, y = make_classification(0, num_classes=8, dim=16, per_class=40)
    parts = label_shard_partition(y, [[j] for j in range(8)])
    ds = FederatedDataset(x, y, parts)
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=16, hidden=24,
                                     num_classes=8))
    return ds, model


def max_param_diff(a, b):
    d = jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), a, b)
    return max(jax.tree.leaves(d))


# ---------------------------------------------------------------------------
# SyncEvent schedules
# ---------------------------------------------------------------------------
def test_uniform_schedule_matches_period_arithmetic():
    """schedule(T) must encode exactly the old step_kind tuples: the highest
    level whose period divides t+1 (Algorithm D.1 break semantics)."""
    spec = HierarchySpec((2, 2, 2), (8, 4, 2))
    topo = UniformTopology(spec)
    sched = topo.schedule(16)
    for t, ev in enumerate(sched):
        lvl = next((l for l, p in enumerate(spec.periods, 1)
                    if (t + 1) % p == 0), None)
        assert ev == (None if lvl is None else SyncEvent(level=lvl)), (t, ev)


def test_grouped_schedule_matches_period_arithmetic():
    topo = GroupedTopology(contiguous(N, 2), G=8, I=(2, 4))
    for t, ev in enumerate(topo.schedule(16)):
        if (t + 1) % 8 == 0:
            assert ev == SyncEvent(level=1)
        else:
            groups = tuple(bool((t + 1) % Ii == 0) for Ii in (2, 4))
            if not any(groups):
                assert ev is None
            elif all(groups):
                assert ev == SyncEvent(level=2)
            else:
                assert ev == SyncEvent(level=2, groups=groups)


def test_events_are_hashable_jit_keys():
    a = SyncEvent(level=2, groups=(True, False))
    b = SyncEvent(level=2, groups=(True, False))
    assert a == b and hash(a) == hash(b) and a != SyncEvent(level=2)
    assert len({a, b, SyncEvent(level=1)}) == 2


def test_compile_schedule_folds_local_blocks():
    topo = make_topology("two_level", n=N, N=2, G=8, I=4)
    rounds = compile_schedule(topo.schedule(18))
    assert rounds == (Round(4, SyncEvent(level=2)), Round(4, SyncEvent(level=1)),
                      Round(4, SyncEvent(level=2)), Round(4, SyncEvent(level=1)),
                      Round(2, None))


# ---------------------------------------------------------------------------
# make_topology registry
# ---------------------------------------------------------------------------
def test_make_topology_registry():
    t1 = make_topology("uniform", spec=two_level(N, 2, 8, 2))
    t2 = make_topology("two_level", n=N, N=2, G=8, I=2)
    assert t1.schedule(8) == t2.schedule(8)
    t3 = make_topology("local_sgd", n=N, P=4)
    assert isinstance(t3, UniformTopology) and t3.periods == (4,)
    t4 = make_topology("grouped", grouping=contiguous(N, 2), G=8, I=2)
    assert isinstance(t4, GroupedTopology)
    # spec/grouping coercion
    assert isinstance(make_topology(local_sgd(N, 2)), UniformTopology)
    assert isinstance(make_topology(contiguous(N, 2), G=4, I=2),
                      GroupedTopology)
    with pytest.raises(KeyError):
        make_topology("ring")


def test_make_aggregator_resolution():
    assert isinstance(make_aggregator(None), MeanAggregator)
    assert isinstance(make_aggregator(None, sync_dtype="bfloat16"),
                      CompressedAggregator)
    assert make_aggregator(None, sync_dtype="float32").accum_dtype == jnp.float32
    assert isinstance(make_aggregator("sign"), SignSGDAggregator)
    inst = WeightedAggregator(np.ones(N))
    assert make_aggregator(inst) is inst
    with pytest.raises(KeyError):
        make_aggregator("median")


# ---------------------------------------------------------------------------
# every aggregator x both topologies through the single aggregate() entry
# ---------------------------------------------------------------------------
AGGS = [MeanAggregator(), CompressedAggregator(), SignSGDAggregator(),
        WeightedAggregator(np.arange(1, N + 1, dtype=float))]


@pytest.mark.parametrize("agg", AGGS, ids=lambda a: type(a).__name__)
@pytest.mark.parametrize("kind", ["uniform", "grouped"])
def test_aggregators_work_with_both_topologies(agg, kind):
    if kind == "uniform":
        topo = make_topology("uniform", spec=two_level(N, 2, 8, 4),
                             aggregator=agg)
    else:
        topo = make_topology("grouped", grouping=contiguous(N, 2), G=8, I=4,
                             aggregator=agg)
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(N, 3, 5)), jnp.float32)}
    for ev in (SyncEvent(level=2), SyncEvent(level=1)):
        out = topo.aggregate(tree, ev)
        w = out["w"]
        assert w.shape == (N, 3, 5) and w.dtype == jnp.float32
        if ev.level == 1:  # global: every worker identical
            assert float(jnp.abs(w - w[0:1]).max()) == 0.0
        else:  # local: identical within each contiguous group of 4
            assert float(jnp.abs(w[:4] - w[0:1]).max()) == 0.0
            assert float(jnp.abs(w[4:] - w[4:5]).max()) == 0.0


def test_event_weights_match_weighted_aggregator():
    """Per-worker weights carried ON the event must weight the mean exactly
    like the same weights in a WeightedAggregator."""
    w = np.arange(1, N + 1, dtype=float)
    rng = np.random.default_rng(5)
    tree = {"w": jnp.asarray(rng.normal(size=(N, 4)), jnp.float32)}
    for make in (lambda a: make_topology("uniform", spec=two_level(N, 2, 8, 4),
                                         aggregator=a),
                 lambda a: make_topology("grouped", grouping=contiguous(N, 2),
                                         G=8, I=4, aggregator=a)):
        via_event = make(None).aggregate(
            tree, SyncEvent(level=2, weights=tuple(w)))
        via_agg = make(WeightedAggregator(w)).aggregate(tree, SyncEvent(level=2))
        assert max_param_diff(via_event, via_agg) < 1e-6


def test_uniform_rejects_partial_group_events():
    topo = make_topology("two_level", n=N, N=2, G=8, I=4)
    with pytest.raises(AssertionError):
        topo.aggregate({"w": jnp.zeros((N, 2))},
                       SyncEvent(level=2, groups=(True, False)))


def test_named_aggregator_honours_sync_dtype():
    """--aggregator sign --sync-dtype bfloat16 must not silently run f32."""
    from repro.core import make_aggregator
    a = make_aggregator("sign", sync_dtype="bfloat16")
    assert a.accum_dtype == jnp.bfloat16
    b = make_aggregator("compressed", sync_dtype="float32")
    assert b.accum_dtype == jnp.float32


def test_sync_counts_match_comm_model():
    spec = HierarchySpec((2, 2, 2), (8, 4, 2))
    counts = spec.sync_counts(16)
    assert counts == (2, 2, 4)  # t+1 in {8,16} / {4,12} / {2,6,10,14}
    assert sum(counts) == sum(ev is not None
                              for ev in UniformTopology(spec).schedule(16))


def test_mean_and_weighted_agree_for_uniform_weights():
    tree = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(N, 4)),
                             jnp.float32)}
    for make in (lambda a: make_topology("uniform", spec=two_level(N, 2, 8, 4),
                                         aggregator=a),
                 lambda a: make_topology("grouped", grouping=contiguous(N, 2),
                                         G=8, I=4, aggregator=a)):
        m = make(MeanAggregator()).aggregate(tree, SyncEvent(level=1))
        w = make(WeightedAggregator(np.full(N, 0.25))).aggregate(
            tree, SyncEvent(level=1))
        assert max_param_diff(m, w) < 1e-6


def test_signsgd_majority_vote_semantics():
    topo = make_topology("local_sgd", n=4, P=1, aggregator="sign")
    x = jnp.asarray([[1.0], [2.0], [-3.0], [0.5]])
    out = topo.aggregate({"w": x}, SyncEvent(level=1))["w"]
    # majority of signs is +, magnitude is mean|x| = 1.625
    assert float(jnp.abs(out - 1.625).max()) < 1e-6
    tie = jnp.asarray([[1.0], [-1.0], [2.0], [-2.0]])
    out = topo.aggregate({"w": tie}, SyncEvent(level=1))["w"]
    assert float(jnp.abs(out).max()) == 0.0  # exact tie collapses to 0


def test_bf16_parity_between_topologies():
    """The compressed payload (once a Uniform-only flag) must produce the
    same aggregate through both topologies on a uniform grouping."""
    rng = np.random.default_rng(2)
    tree = {"w": jnp.asarray(rng.normal(size=(N, 6)), jnp.float32)}
    tu = make_topology("uniform", spec=two_level(N, 2, 8, 4),
                       sync_dtype="bfloat16")
    tg = make_topology("grouped", grouping=contiguous(N, 2), G=8, I=4,
                       sync_dtype="bfloat16")
    assert isinstance(tu.aggregator, CompressedAggregator)
    assert isinstance(tg.aggregator, CompressedAggregator)
    for ev in (SyncEvent(level=2), SyncEvent(level=1)):
        diff = max_param_diff(tu.aggregate(tree, ev), tg.aggregate(tree, ev))
        assert diff < 2e-2, (ev, diff)  # both bf16-rounded means


def test_masked_partial_participation_grouped_equivalence():
    """A (n,) participation mask on GroupedTopology must equal dropping the
    masked workers from the mean by hand (level 2) and the mean of
    participant group-means (level 1)."""
    g = contiguous(N, 2)
    topo = make_topology("grouped", grouping=g, G=8, I=4)
    rng = np.random.default_rng(3)
    x = rng.normal(size=(N, 5)).astype(np.float32)
    mask = np.array([True, True, False, False, True, False, True, False])
    out = topo.aggregate({"w": jnp.asarray(x)}, SyncEvent(level=2),
                         mask=jnp.asarray(mask))["w"]
    for i in range(g.N):
        members = g.members(i)
        expect = x[members][mask[members]].mean(0)
        np.testing.assert_allclose(np.asarray(out[members]),
                                   np.tile(expect, (len(members), 1)),
                                   rtol=1e-5)
    out = topo.aggregate({"w": jnp.asarray(x)}, SyncEvent(level=1),
                         mask=jnp.asarray(mask))["w"]
    gm = np.stack([x[g.members(i)][mask[g.members(i)]].mean(0)
                   for i in range(g.N)])
    np.testing.assert_allclose(np.asarray(out), np.tile(gm.mean(0), (N, 1)),
                               rtol=1e-5)


def test_masked_uniform_matches_masked_grouped(setup):
    """Same mask, same uniform grouping => same trained params through
    either topology's masked path.  (Participation is balanced across
    groups: uniform's global mean is a flat participant mean, grouped's is a
    mean of group means — they only coincide at equal per-group counts.)"""
    ds, model = setup
    mask = np.array([True, True, False, False, True, False, True, False])

    def train(topo):
        eng = HSGD(model.loss, sgd(0.05), topo, jit=True)
        st = eng.init(jax.random.PRNGKey(0), model.init)
        for t in range(8):
            st, _ = eng.step(st, jax.tree.map(jnp.asarray, ds.batch(t, 8)),
                             mask=mask)
        return st

    s1 = train(make_topology("uniform", spec=two_level(N, 2, 8, 4)))
    s2 = train(make_topology("grouped", grouping=contiguous(N, 2), G=8, I=4))
    assert max_param_diff(s1.params, s2.params) < 1e-5


# ---------------------------------------------------------------------------
# schedule-compiled executor
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("topo_fn", [
    lambda: make_topology("two_level", n=N, N=2, G=8, I=4),
    lambda: make_topology("uniform",
                          spec=HierarchySpec((2, 2, 2), (8, 4, 2))),
    lambda: make_topology("grouped", grouping=contiguous(N, 2), G=8, I=(2, 4)),
    lambda: make_topology("two_level", n=N, N=2, G=8, I=4, aggregator="sign"),
], ids=["two_level", "three_level", "grouped_hetero", "sign"])
def test_run_rounds_equals_per_step(setup, topo_fn):
    """run_rounds must reproduce the per-step step() trajectory bitwise."""
    ds, model = setup
    batch_fn = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8))
    T = 18  # includes a trailing partial round

    eng_a = HSGD(model.loss, sgd(0.05), topo_fn(), jit=True)
    st_a = eng_a.init(jax.random.PRNGKey(0), model.init)
    step_metrics = []
    for t in range(T):
        st_a, m = eng_a.step(st_a, batch_fn(t))
        step_metrics.append({k: float(v) for k, v in m.items()})

    eng_b = HSGD(model.loss, sgd(0.05), topo_fn(), jit=True)
    st_b = eng_b.init(jax.random.PRNGKey(0), model.init)
    st_b, hist = eng_b.run_rounds(st_b, batch_fn, T)

    assert max_param_diff(st_a.params, st_b.params) == 0.0
    assert int(st_b.step) == T
    assert [rec["t"] for rec in hist] == list(range(1, T + 1))
    for rec, m in zip(hist, step_metrics):
        assert abs(rec["ce"] - m["ce"]) < 1e-5


def test_run_rounds_resumes_mid_schedule(setup):
    """Starting run_rounds from a nonzero state.step must continue the
    schedule phase-correctly (events depend on absolute t)."""
    ds, model = setup
    batch_fn = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8))
    topo = make_topology("two_level", n=N, N=2, G=8, I=4)

    eng_a = HSGD(model.loss, sgd(0.05), topo, jit=True)
    st_a = eng_a.init(jax.random.PRNGKey(0), model.init)
    for t in range(16):
        st_a, _ = eng_a.step(st_a, batch_fn(t))

    eng_b = HSGD(model.loss, sgd(0.05), topo, jit=True)
    st_b = eng_b.init(jax.random.PRNGKey(0), model.init)
    st_b, _ = eng_b.run_rounds(st_b, batch_fn, 6)   # ends mid-round
    st_b, _ = eng_b.run_rounds(st_b, batch_fn, 10)  # resumes at t=6
    assert max_param_diff(st_a.params, st_b.params) == 0.0


def test_run_rounds_eval_at_boundaries(setup):
    ds, model = setup
    batch_fn = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8))
    topo = make_topology("two_level", n=N, N=2, G=8, I=4)
    eng = HSGD(model.loss, sgd(0.05), topo, jit=True)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    st, hist = eng.run_rounds(st, batch_fn, 16, eval_every=8,
                              eval_fn=lambda s, t: {"evaluated_at": t + 1})
    assert [r["t"] for r in hist if "evaluated_at" in r] == [8, 16]


def test_run_records_per_step_metrics(setup):
    """run() history must not be empty without eval_every (regression)."""
    ds, model = setup
    topo = make_topology("two_level", n=N, N=2, G=4, I=2)
    eng = HSGD(model.loss, sgd(0.05), topo, jit=True)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    st, hist = run(eng, st, lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8)),
                   T=6)
    assert len(hist) == 6
    assert all("ce" in rec and rec["t"] == i + 1 for i, rec in enumerate(hist))
    # eval results merge into the matching step's record
    st, hist = run(eng, st, lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 8)),
                   T=4, eval_every=2, eval_fn=lambda s, t: {"ev": True})
    assert [("ev" in rec) for rec in hist] == [False, True, False, True]


def test_grouped_topology_size_weighted_global():
    """Grouping.size_weights through WeightedAggregator reproduces the
    unweighted-mean-of-group-means on a NON-uniform grouping at level 2."""
    g = Grouping((0, 0, 0, 1, 1, 2, 2, 2))
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)
    topo = make_topology("grouped", grouping=g, G=4, I=2,
                         aggregator=WeightedAggregator(g.size_weights()))
    out = topo.aggregate({"w": x}, SyncEvent(level=2))["w"]
    a = np.asarray(g.assignment)
    for i in range(g.N):  # weights are constant within a group => group mean
        np.testing.assert_allclose(np.asarray(out[a == i]),
                                   np.tile(np.asarray(x[a == i]).mean(0),
                                           (sum(a == i), 1)), rtol=1e-5)


# ---------------------------------------------------------------------------
# denominator guards (accumulation-dtype-aware)
# ---------------------------------------------------------------------------
def test_denominator_guard_survives_half_precision_all_masked():
    """The weighted-mean denominator floor must live in the ACCUMULATION
    dtype: the old literal ``1e-9`` underflows to 0 in f16 accumulation, so
    an all-masked group divided 0/0 = NaN.  With ``denominator_floor`` the
    quotient is an exact, finite 0 in every accumulation dtype."""
    from repro.core.aggregators import (axis_weighted_mean, denominator_floor,
                                        named_axis_weighted_mean,
                                        segment_weighted_mean)
    # the bug being fixed: the old guard is literally zero in f16
    assert float(jnp.asarray(1e-9, jnp.float16)) == 0.0
    for acc in (jnp.float16, jnp.bfloat16, jnp.float32):
        assert float(denominator_floor(acc)) > 0.0

    v = jnp.ones((4, 3), jnp.float16)
    w = jnp.zeros((4, 1), jnp.float16)          # every worker masked out
    out = axis_weighted_mean(v, w, (0,), jnp.float16)
    assert np.isfinite(np.asarray(out, jnp.float32)).all()

    membership = jnp.asarray(np.eye(2).repeat(2, axis=1), jnp.float16)
    out = segment_weighted_mean(v, jnp.zeros((4,), jnp.float16), membership,
                                jnp.float16)
    assert np.isfinite(np.asarray(out, jnp.float32)).all()

    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    f = shard_map(
        lambda vv, ww: named_axis_weighted_mean(vv, ww[0], ("x",),
                                                jnp.float16),
        mesh=mesh, in_specs=(P("x"), P("x")), out_specs=P("x"),
        check_rep=False)
    out = f(jnp.ones((1, 3), jnp.float16), jnp.zeros((1,), jnp.float16))
    assert np.isfinite(np.asarray(out, jnp.float32)).all()


def test_denominator_guard_keeps_f32_weighted_means_exact():
    """For real (nonzero) f32 weight sums the floor never engages, so the
    fix is bitwise-invisible to every existing weighted trajectory."""
    from repro.core.aggregators import axis_weighted_mean
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.normal(size=(8, 5)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, size=(8, 1)), jnp.float32)
    got = axis_weighted_mean(v, w, (0,), jnp.float32)
    want = (v * w).sum(0, keepdims=True) / w.sum(0, keepdims=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
