"""Property-based tests (hypothesis) for the paper's algebra:
eq. (10) partition identity, Lemma 1/2 exact expectations under exhaustive
random grouping, sandwich inequalities (16)(17)(23)(24), bound recoveries,
and the Appendix A.1 mixing-matrix spectrum claim."""
import itertools
import math

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Grouping, contiguous, downward_divergence_avg,
                        global_divergence, group_iid, group_noniid,
                        partition_residual, random_grouping, upward_divergence)
from repro.core import theory as th

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# eq. (10): partition identity — exact for ANY gradients and ANY grouping
# ---------------------------------------------------------------------------
@given(st.integers(2, 12), st.integers(1, 4), st.integers(0, 10**6))
def test_partition_identity(n, dim, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(n, dim)))
    N = rng.integers(1, n + 1)
    assignment = rng.integers(0, N, size=n)
    # densify group ids
    _, dense = np.unique(assignment, return_inverse=True)
    grp = Grouping(tuple(dense))
    res = float(partition_residual(g, grp))
    scale = float(global_divergence(g)) + 1e-9
    assert abs(res) / scale < 1e-5


# ---------------------------------------------------------------------------
# Lemmas 1 & 2: E_S[upward] == (N-1)/(n-1) * eps_w^2 exactly (eq. C.5),
# via exhaustive enumeration of equal-size groupings for small n
# ---------------------------------------------------------------------------
def _all_equal_partitions(n, N):
    """All ways to split range(n) into N unordered groups of size n//N."""
    k = n // N
    items = list(range(n))

    def rec(remaining):
        if not remaining:
            yield []
            return
        first = remaining[0]
        rest = remaining[1:]
        for combo in itertools.combinations(rest, k - 1):
            grp = (first,) + combo
            left = [x for x in rest if x not in combo]
            for tail in rec(left):
                yield [grp] + tail

    yield from rec(items)


@pytest.mark.parametrize("n,N", [(4, 2), (6, 2), (6, 3)])
def test_lemma1_lemma2_exhaustive(n, N):
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(n, 3)))
    gbar = g.mean(0)
    eps_w2 = float(jnp.mean(jnp.sum((g - gbar) ** 2, axis=1)))
    ups, downs = [], []
    for parts in _all_equal_partitions(n, N):
        a = np.empty(n, np.int64)
        for i, grp in enumerate(parts):
            for j in grp:
                a[j] = i
        grp_obj = Grouping(tuple(a))
        ups.append(float(upward_divergence(g, grp_obj)))
        downs.append(float(downward_divergence_avg(g, grp_obj)))
    exp_up = np.mean(ups)
    exp_down = np.mean(downs)
    np.testing.assert_allclose(exp_up, (N - 1) / (n - 1) * eps_w2, rtol=1e-5)
    np.testing.assert_allclose(exp_down,
                               (1 - (N - 1) / (n - 1)) * eps_w2, rtol=1e-5)
    # lemma statements as bounds with eps_tilde >= eps_w
    assert exp_up <= th.lemma1_rhs(n, N, eps_w2) + 1e-9
    assert exp_down <= th.lemma2_rhs(n, N, eps_w2) + 1e-9


# ---------------------------------------------------------------------------
# sandwich inequalities
# ---------------------------------------------------------------------------
@given(st.integers(1, 6), st.integers(1, 5), st.integers(1, 5),
       st.integers(2, 6))
def test_sandwich_16_17(logn, m_i, m_g, N):
    n = N * (2 ** logn)
    I = 2 ** m_i
    G = I * (2 ** m_g)
    lo, mid, hi = th.sandwich_noise_terms(n, N, G, I)
    assert lo - 1e-12 <= mid <= hi + 1e-12
    lo, mid, hi = th.sandwich_div_terms(n, N, G, I)
    assert lo - 1e-12 <= mid <= hi + 1e-12


@given(st.integers(2, 4), st.integers(2, 4), st.integers(2, 3),
       st.integers(1, 3))
def test_sandwich_multilevel_23_24(n1, n2, n3, base):
    group_sizes = (n1, n2, n3)
    periods = (base * 8, base * 4, base * 2)
    n = n1 * n2 * n3
    M = 3
    a1 = np.mean([th.theorem3_A1(l, periods, group_sizes) for l in (1, 2)])
    a2 = np.mean([th.theorem3_A2(l, periods, group_sizes) for l in (1, 2)])
    assert (1 - 1 / n) * periods[-1] - 1e-9 <= a1 <= (1 - 1 / n) * periods[0] + 1e-9
    assert periods[-1] ** 2 - 1e-9 <= a2 <= periods[0] ** 2 + 1e-9


# ---------------------------------------------------------------------------
# bound recoveries and orderings
# ---------------------------------------------------------------------------
@given(st.integers(2, 64), st.integers(1, 6), st.floats(0.0, 2.0),
       st.floats(0.0, 2.0))
def test_thm1_recovers_corollary1(n, logp, sigma2, eps2):
    P = 2 ** logp
    gamma = 0.9 * th.lr_cap(P, 1.0)
    b1 = th.theorem1_bound(gamma=gamma, T=500, L=1.0, sigma2=sigma2,
                           f0_minus_fstar=1.0, n=n, G=P, group_sizes=[n],
                           I_periods=[P], eps_up2=0.0, eps_down2=[eps2])
    b2 = th.corollary1_local_sgd_bound(gamma=gamma, T=500, L=1.0,
                                       sigma2=sigma2, f0_minus_fstar=1.0,
                                       n=n, P=P, eps_tilde2=eps2)
    assert math.isclose(b1, b2, rel_tol=1e-12)


@given(st.integers(1, 4), st.integers(1, 3), st.integers(1, 3),
       st.floats(0.01, 1.0), st.floats(0.0, 1.0))
def test_thm3_reduces_to_thm2(logN, logK, m, sigma2, eps2):
    N, K = 2 ** logN, 2 ** logK
    n = N * K
    if n < 4:
        return
    I = 4
    G = I * (2 ** m)
    gamma = 0.9 * th.lr_cap(G, 1.0)
    kw = dict(gamma=gamma, T=1000, L=1.0, sigma2=sigma2,
              f0_minus_fstar=1.0, eps_tilde2=eps2)
    b2 = th.theorem2_bound(n=n, N=N, G=G, I=I, **kw)
    b3 = th.theorem3_bound(periods=(G, I), group_sizes=(N, K), **kw)
    assert math.isclose(b2, b3, rel_tol=1e-10)


@given(st.integers(1, 4), st.floats(0.1, 1.0), st.floats(0.1, 1.0))
def test_hsgd_bound_between_local_sgd_bounds(logN, sigma2, eps2):
    """Theorem 2's bound sits between local SGD at P=I and P=G (Remark 4)."""
    N = 2 ** logN
    n = N * 4
    I, G = 4, 16
    gamma = 0.9 * th.lr_cap(G, 1.0)
    kw = dict(gamma=gamma, T=2000, L=1.0, sigma2=sigma2, f0_minus_fstar=1.0)
    mid = th.theorem2_bound(n=n, N=N, G=G, I=I, eps_tilde2=eps2, **kw)
    lo = th.corollary1_local_sgd_bound(n=n, P=I, eps_tilde2=eps2, **kw)
    hi = th.corollary1_local_sgd_bound(n=n, P=G, eps_tilde2=eps2, **kw)
    assert lo - 1e-12 <= mid <= hi + 1e-12


def test_table1_ours_tightest_representative():
    """Table 1 claim at a representative operating point: our bound is the
    tightest; Liu'20 compares at sigma2=0, Castiglia'21 at eps2=0."""
    n, N, T, G, I = 32, 4, 10_000, 50, 5
    s2, e2 = 1.0, 1.0
    ours = th.table1_ours(n, N, T, G, I, s2, e2)
    yu = th.table1_yu2019(n, T, G, s2, e2)
    assert ours < yu
    ours_nonoise = th.table1_ours(n, N, T, G, I, 0.0, e2)
    liu = th.table1_liu2020(n, T, G, e2)
    assert ours_nonoise < liu
    ours_iid = th.table1_ours(n, N, T, G, I, s2, 0.0)
    cast = th.table1_castiglia2021(n, T, G, I, s2)
    assert ours_iid < cast


# ---------------------------------------------------------------------------
# groupings
# ---------------------------------------------------------------------------
def test_mixing_matrix_spectrum_appendix_a1():
    """A_loc has eigenvalue 1 with multiplicity N (so decentralized-SGD
    analysis, which needs |lambda_2| < 1, does not apply)."""
    grp = contiguous(12, 3)
    A = grp.local_matrix()
    ev = np.sort(np.abs(np.linalg.eigvals(A)))[::-1]
    assert np.sum(np.isclose(ev, 1.0)) == 3
    # doubly stochastic
    np.testing.assert_allclose(A.sum(1), 1.0)
    np.testing.assert_allclose(A.sum(0), 1.0)


def test_group_iid_minimizes_upward_divergence():
    rng = np.random.default_rng(0)
    labels = np.arange(16) % 8
    # gradient direction determined by label
    basis = rng.normal(size=(8, 5))
    g = jnp.asarray(basis[labels] + 0.01 * rng.normal(size=(16, 5)))
    up_iid = float(upward_divergence(g, group_iid(labels, 2)))
    up_non = float(upward_divergence(g, group_noniid(labels, 2)))
    assert up_iid < 0.05 * up_non


@given(st.integers(1, 3), st.integers(1, 3), st.integers(0, 100))
def test_random_grouping_valid(logN, logK, seed):
    N, K = 2 ** logN, 2 ** logK
    grp = random_grouping(N * K, N, seed)
    assert sorted(grp.sizes) == [K] * N


# ---------------------------------------------------------------------------
# planner + diversity grouping (operationalizing Remark 2 / the conclusion)
# ---------------------------------------------------------------------------
def test_planner_prefers_hsgd_when_far_rounds_expensive():
    from repro.core import CommModel, best_under_budget, enumerate_plans, pareto_front
    comm = CommModel(compute_s=0.004, local_round_s=0.0003,
                     global_round_s=0.0045)  # paper Table E.1 CNN numbers
    plans = enumerate_plans(n=32, T=5000, L=1.0, sigma2=1.0, eps_tilde2=1.0,
                            f0_minus_fstar=1.0, comm=comm)
    assert plans
    # pure-sync extreme (G=I small) must be strictly slower wall-clock than
    # an H-SGD plan with the same bound neighborhood
    front = pareto_front(plans)
    assert len(front) >= 2
    # budget slightly above the cheapest plan: best plan uses I < G
    cheapest = min(p.wall_s for p in plans)
    best = best_under_budget(plans, cheapest * 1.15)
    assert best is not None and best.I < best.G


def test_planner_budget_monotonicity():
    from repro.core import CommModel, best_under_budget, enumerate_plans
    comm = CommModel(0.004, 0.0003, 0.0045)
    plans = enumerate_plans(n=16, T=2000, L=1.0, sigma2=0.5, eps_tilde2=0.5,
                            f0_minus_fstar=1.0, comm=comm)
    b_lo = best_under_budget(plans, min(p.wall_s for p in plans) * 1.05)
    b_hi = best_under_budget(plans, max(p.wall_s for p in plans))
    assert b_hi.bound <= b_lo.bound + 1e-12  # more budget never hurts


def test_diversity_grouping_beats_random_upward_divergence():
    from repro.core import diversity_grouping, random_grouping
    rng = np.random.default_rng(0)
    # 16 workers, gradients clustered by 4 latent classes
    basis = rng.normal(size=(4, 8)) * 3
    labels = np.arange(16) % 4
    g = basis[labels] + 0.05 * rng.normal(size=(16, 8))
    gj = jnp.asarray(g)
    div = upward_divergence(gj, diversity_grouping(g, 4))
    rand = np.mean([float(upward_divergence(gj, random_grouping(16, 4, s)))
                    for s in range(20)])
    assert float(div) < 0.25 * rand, (float(div), rand)
