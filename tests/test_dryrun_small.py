"""Small-mesh dry-run integration test (subprocess: device-count override
must precede jax init, so it cannot run in the main test process)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.configs.base import InputShape
from repro.core import HSGD, HierarchySpec, SyncEvent, UniformTopology
from repro.core.hsgd import HSGDState
from repro.models import build_model
from repro.optim import sgd
from repro.launch.partitioning import batch_shardings, params_shardings
from repro.roofline import analyze_compiled

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = dataclasses.replace(reduced(get_config("qwen2-0.5b")),
                          num_heads=4, num_kv_heads=2, head_dim=32)
model = build_model(cfg)
opt = sgd(1e-2)
spec = HierarchySpec((2, 2), (4, 2))
eng = HSGD(model.loss, opt, UniformTopology(spec), jit=False)
n = 4

p0 = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
o0 = jax.eval_shape(opt.init, p0)
lead = lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype)
p_spec = jax.tree.map(lead, p0)
o_spec = jax.tree.map(lead, o0)
state_spec = HSGDState(p_spec, o_spec, jax.ShapeDtypeStruct((), jnp.int32))
batch_spec = {k: jax.ShapeDtypeStruct((n, 2, 32), jnp.int32)
              for k in ("tokens", "targets")}

state_sh = HSGDState(
    params=params_shardings(mesh, p_spec, lead_worker=("pod", "data")),
    opt_state=params_shardings(mesh, o_spec, lead_worker=("pod", "data")),
    step=NamedSharding(mesh, P()))
batch_sh = batch_shardings(mesh, batch_spec, lead_worker=("pod", "data"))

out = {}
for kname, kind in [("local", None), ("local_sync", SyncEvent(level=2)),
                    ("global_sync", SyncEvent(level=1))]:
    step = eng.step_fn(kind)
    fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                 out_shardings=(state_sh, None))
    compiled = fn.lower(state_spec, batch_spec).compile()
    rep = analyze_compiled(kname, compiled, pod_size=4)
    out[kname] = {"flops": rep.flops_per_chip,
                  "coll_intra": rep.coll_intra,
                  "coll_cross": rep.coll_cross}

# REAL EXECUTION on the 8 host devices: the distributed step must agree
# with the single-device engine bitwise-ish.
import repro.data.synthetic as syn
state = eng.init(jax.random.PRNGKey(0), model.init)
batch = jax.tree.map(
    lambda s: jax.random.randint(jax.random.PRNGKey(1), s.shape, 0,
                                 cfg.vocab_size), batch_spec)
step = eng.step_fn(SyncEvent(level=1))
fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
             out_shardings=(state_sh, None))
state_sharded = jax.device_put(state, state_sh)
batch_sharded = jax.device_put(batch, batch_sh)
new_sharded, m1 = fn(state_sharded, batch_sharded)
new_local, m2 = eng.step_fn(SyncEvent(level=1))(state, batch)
diff = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.abs(jnp.asarray(a, jnp.float32) -
                               jnp.asarray(b, jnp.float32)).max()),
    new_sharded.params, new_local.params)))
out["exec_param_diff"] = diff
out["loss_diff"] = abs(float(m1["ce"]) - float(m2["ce"]))
print("RESULT" + json.dumps(out))
"""


@pytest.mark.slow
def test_small_mesh_dryrun_and_execution():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    # sync semantics visible in the collectives: global crosses pods
    assert out["global_sync"]["coll_cross"] > 0
    assert out["local_sync"]["coll_cross"] <= out["global_sync"]["coll_cross"]
    assert out["local"]["flops"] > 0
    # distributed execution == local execution
    assert out["exec_param_diff"] < 1e-5, out
    assert out["loss_diff"] < 1e-5
