"""The simulated-time runtime: straggler samplers, the event-driven clock,
deadline-elastic participation, and their integration contracts —

* ``HSGD(..., runtime=None)`` (the default) is bitwise-identical: same
  trajectory AND the same lowered jaxpr as a runtime-full-barrier engine
  (the clock is host-side accounting, invisible to XLA);
* the elastic-participation contract: a worker dropped from a sync keeps
  its EXACT post-update params, opt state and unconsumed comms residuals
  (extends the PR-3 partial-participation tests in test_comms.py);
* determinism: clocks are seed-reproducible and monotone, and sampler
  draws are pure in (seed, t) so policies compare on identical compute
  times — the basis of the elastic-never-slower invariant.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comms import Comms
from repro.core import (HSGD, CommModel, GroupedTopology, HierarchySpec,
                        Round, contiguous, make_topology)
from repro.core.topology import SyncEvent
from repro.data import (FederatedDataset, label_shard_partition,
                        make_classification)
from repro.models import SimpleConfig, SimpleModel
from repro.optim import momentum, sgd
from repro.runtime import (DeadlineElastic, FullBarrier, LinkModel,
                           RuntimeModel, make_policy, make_runtime,
                           make_straggler)

SPEC = HierarchySpec((2, 4), (8, 2))


@pytest.fixture(scope="module")
def setup():
    x, y = make_classification(0, num_classes=8, dim=16, per_class=40)
    parts = label_shard_partition(y, [[j] for j in range(8)])
    ds = FederatedDataset(x, y, parts)
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=16, hidden=24,
                                     num_classes=8))
    return ds, model


def batch_fn(ds, bs=8):
    return lambda t: jax.tree.map(jnp.asarray, ds.batch(t, bs))


def max_diff(a, b):
    d = jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), a, b)
    return max(jax.tree.leaves(d))


# ---------------------------------------------------------------------------
# straggler samplers
# ---------------------------------------------------------------------------
def test_samplers_deterministic_and_order_free():
    for spec in ("none", "fixed:0.25:4", "lognormal:0.7",
                 "bursty:0.1:0.3:5"):
        a = make_straggler(spec, n=8, seed=3)
        b = make_straggler(spec, n=8, seed=3)
        # query b out of order: draws must be pure in (seed, t)
        out_b = {t: b.multipliers(t) for t in (5, 0, 3, 1, 4, 2)}
        for t in range(6):
            np.testing.assert_array_equal(a.multipliers(t), out_b[t])
        assert (a.multipliers(0) > 0).all()
    # different seeds differ (for regimes with randomness)
    a = make_straggler("lognormal:0.7", n=8, seed=0)
    b = make_straggler("lognormal:0.7", n=8, seed=1)
    assert not np.array_equal(a.multipliers(0), b.multipliers(0))


def test_sampler_specs_and_registry():
    s = make_straggler("fixed:0.5:3", n=8, seed=0)
    assert s.slow_set.sum() == 4 and set(np.unique(s.multipliers(7))) == {1.0, 3.0}
    assert make_straggler(None, n=4).multipliers(0).tolist() == [1.0] * 4
    # rebinding an instance re-seeds it (RuntimeModel carries a template)
    s2 = make_straggler(s, n=6, seed=9)
    assert s2.n == 6 and s2.params() == s.params()
    with pytest.raises(KeyError):
        make_straggler("nope", n=4)
    with pytest.raises(ValueError):
        make_straggler("lognormal:1:2:3:4", n=4)


def test_bursty_chain_is_markov_and_reproducible():
    s = make_straggler("bursty:0.5:0.5:7", n=64, seed=2)
    states = [(s.multipliers(t) > 1).mean() for t in range(40)]
    assert 0.2 < np.mean(states[10:]) < 0.8  # mixes to the 50% stationary
    s2 = make_straggler("bursty:0.5:0.5:7", n=64, seed=2)
    np.testing.assert_array_equal(s.multipliers(39), s2.multipliers(39))


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------
def test_make_policy_parsing():
    assert isinstance(make_policy(None), FullBarrier)
    assert isinstance(make_policy("full"), FullBarrier)
    p = make_policy(2.0)
    assert isinstance(p, DeadlineElastic) and p.deadline(1) == 2.0
    p = make_policy("L1:2.0,L2:0.5")
    assert p.deadline(1) == 2.0 and p.deadline(2) == 0.5
    assert p.deadline(3) == np.inf  # unspecified level: full barrier there
    with pytest.raises(ValueError):
        make_policy("L1:")
    # admit: anchored on the fastest member, so never empty
    arr = np.array([1.0, 1.4, 9.0])
    assert make_policy(0.5).admit(1, arr).tolist() == [True, True, False]
    assert make_policy(None).admit(1, arr).all()


# ---------------------------------------------------------------------------
# the clock
# ---------------------------------------------------------------------------
def _drive(clock, topo, T):
    times = []
    for t in range(T):
        clock.advance(t)
        ev = topo.event_at(t)
        if ev is not None:
            clock.sync(ev)
        times.append(clock.time_s)
    return times


def test_clock_monotone_and_seed_reproducible():
    topo = make_topology("uniform", spec=SPEC)
    rt = RuntimeModel(compute_s=1.0, straggler="lognormal:0.8", policy=0.5,
                      seed=5)
    t1 = _drive(rt.clock(topo, 1000), topo, 32)
    t2 = _drive(rt.clock(topo, 1000), topo, 32)
    assert t1 == t2                                    # seed-reproducible
    assert all(a <= b for a, b in zip(t1, t1[1:]))     # monotone
    assert t1[-1] > 0.0
    ck = rt.clock(topo, 1000)
    prev = ck.clocks.copy()
    for t in range(32):
        ck.advance(t)
        assert (ck.clocks >= prev - 1e-12).all()
        prev = ck.clocks.copy()
        ev = topo.event_at(t)
        if ev is not None:
            ck.sync(ev)
            assert (ck.clocks >= prev - 1e-12).all()   # barriers only wait
            prev = ck.clocks.copy()


def test_clock_elastic_never_slower_pointwise():
    topo = make_topology("uniform", spec=SPEC)
    for regime in ("none", "fixed:0.25:6", "lognormal:0.9",
                   "bursty:0.1:0.3:8"):
        full = RuntimeModel(compute_s=1.0, straggler=regime, seed=7)
        el = RuntimeModel(compute_s=1.0, straggler=regime, policy=1.0, seed=7)
        cf, ce = full.clock(topo, 4096), el.clock(topo, 4096)
        for t in range(64):
            cf.advance(t), ce.advance(t)
            ev = topo.event_at(t)
            if ev is not None:
                cf.sync(ev), ce.sync(ev)
            assert (ce.clocks <= cf.clocks + 1e-9).all(), (regime, t)


def test_clock_link_pricing_and_codec_payoff():
    """Sync cost = sum over crossed tiers of latency + bytes/bandwidth —
    so a smaller (compressed) payload buys simulated time."""
    topo = make_topology("uniform", spec=SPEC)
    links = (LinkModel(1.0, 1e3), LinkModel(0.1, 1e4))
    rt = RuntimeModel(compute_s=1.0, links=links)
    big = rt.clock(topo, 10_000)
    small = rt.clock(topo, 1_000)
    assert big.event_cost_s(1) == pytest.approx(1.0 + 10_000 / 1e3 +
                                                0.1 + 10_000 / 1e4)
    assert big.event_cost_s(2) == pytest.approx(0.1 + 10_000 / 1e4)
    t_big = _drive(big, topo, 16)[-1]
    t_small = _drive(small, topo, 16)[-1]
    assert t_small < t_big
    # the homogeneous full-barrier closed form: T*compute + sum of costs
    assert t_big == pytest.approx(16 * 1.0 + 2 * big.event_cost_s(1) +
                                  6 * big.event_cost_s(2))
    with pytest.raises(AssertionError):  # one link per level, enforced
        RuntimeModel(compute_s=1.0, links=(LinkModel(1.0, 1e3),)).clock(
            topo, 1)


def test_clock_grouped_topology_partial_events():
    """GroupedTopology with heterogeneous periods: a partial level-2 event
    barriers only the participating groups — the others' clocks are
    untouched and the event still prices one link crossing."""
    topo = GroupedTopology(contiguous(8, 2), G=8, I=(2, 4))
    rt = RuntimeModel(compute_s=1.0, links=(LinkModel(1.0, 1e9),
                                            LinkModel(0.1, 1e9)))
    ck = rt.clock(topo, 100)
    ck.advance(0), ck.advance(1)
    ev = topo.event_at(1)            # only group 0 (I=2) syncs
    assert ev.groups == (True, False)
    before = ck.clocks.copy()
    assert ck.sync(ev) is None       # nobody dropped
    assert (ck.clocks[:4] > before[:4]).all()        # group 0 paid the link
    np.testing.assert_array_equal(ck.clocks[4:], before[4:])  # group 1 idle
    assert ck.comm_s[2] > 0.0 and ck.comm_s[1] == 0.0


def test_clock_published_model_telemetry():
    """last_admitted / last_sync_time: who made the most recent level-ℓ
    event and when its barrier completed — under elastic drops, the global
    aggregate is published when the ADMITTED workers' barrier closes, well
    before a dropped straggler's own clock gets there."""
    topo = make_topology("uniform", spec=SPEC)
    rt_e = RuntimeModel(compute_s=1.0, straggler="fixed:0.125:8", policy=1.0,
                        seed=0)
    rt_f = RuntimeModel(compute_s=1.0, straggler="fixed:0.125:8", seed=0)
    ce, cf = rt_e.clock(topo, 1000), rt_f.clock(topo, 1000)
    _drive(ce, topo, 8), _drive(cf, topo, 8)
    slow = make_straggler("fixed:0.125:8", n=8, seed=0).slow_set
    assert not ce.last_admitted[1][slow].any()
    assert ce.last_admitted[1].sum() == 7
    assert cf.last_admitted[1].all()
    # publication beats the straggler-gated makespan; full barrier can't
    assert ce.last_sync_time[1] < ce.time_s
    assert cf.last_sync_time[1] == pytest.approx(cf.time_s)
    assert ce.last_sync_time[1] < cf.last_sync_time[1]


def test_make_runtime_resolution():
    assert make_runtime(None) is None
    rt = RuntimeModel(compute_s=2.0)
    assert make_runtime(rt) is rt
    assert make_runtime(compute_s=3.0).compute_s == 3.0
    assert not RuntimeModel(compute_s=1.0).elastic
    assert RuntimeModel(compute_s=1.0, policy=1.0).elastic


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------
def test_runtime_none_is_bitwise_and_jaxpr_identical(setup):
    """The acceptance contract: runtime=None (default) and a full-barrier
    runtime produce the SAME trajectory and the SAME lowered round jaxpr —
    the clock is host-side accounting, invisible to the compiled program."""
    ds, model = setup
    mk = lambda: make_topology("uniform", spec=SPEC)
    e0 = HSGD(model.loss, sgd(0.05), mk())
    e1 = HSGD(model.loss, sgd(0.05), mk(),
              runtime=RuntimeModel(compute_s=1.0))
    s0 = e0.init(jax.random.PRNGKey(0), model.init)
    s1 = e1.init(jax.random.PRNGKey(0), model.init)
    rnd = Round(2, SyncEvent(level=1))
    batches = tuple(batch_fn(ds)(t) for t in range(2))
    from repro.analysis import fingerprint
    assert fingerprint(e0.executor.round_jaxpr(rnd, s0, batches)) == \
        fingerprint(e1.executor.round_jaxpr(rnd, s1, batches))
    s0, h0 = e0.run_rounds(s0, batch_fn(ds), 16)
    s1, h1 = e1.run_rounds(s1, batch_fn(ds), 16)
    assert max_diff(s0.params, s1.params) == 0.0
    assert "sim_time_s" not in h0[0]
    assert h1[0]["sim_time_s"] > 0.0 and "sim_sync_s" in h1[0]
    assert [r["ce"] for r in h0] == [r["ce"] for r in h1]


def test_history_sim_fields(setup):
    ds, model = setup
    topo = make_topology("uniform", spec=SPEC)
    rt = RuntimeModel(compute_s=1.0, straggler="lognormal:0.5", seed=3)
    eng = HSGD(model.loss, sgd(0.05), topo, runtime=rt)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    st, hist = eng.run_rounds(st, batch_fn(ds), 16)
    times = [r["sim_time_s"] for r in hist]
    assert all(a <= b for a, b in zip(times, times[1:]))
    # per-level sync seconds are cumulative and only grow at event steps
    l1 = [r["sim_sync_s"]["L1"] for r in hist]
    assert l1[7] > 0.0 and l1[-1] == pytest.approx(2 * l1[7])
    rep = eng.runtime_report()
    assert rep["time_s"] == pytest.approx(times[-1], abs=1e-5)
    assert eng.runtime_report(st) == rep  # state arg accepted, unused
    assert HSGD(model.loss, sgd(0.05),
                make_topology("uniform", spec=SPEC)).runtime_report() is None


def test_elastic_drop_contract_params_and_opt(setup):
    """THE elastic-participation contract: a worker dropped from a sync has
    exactly the params/opt state of a run whose round ended with NO sync —
    it computed its local updates, then neither contributed to nor received
    the aggregate; admitted workers got the (masked) aggregate."""
    ds, model = setup
    mk = lambda: make_topology("uniform", spec=HierarchySpec((2, 4), (4, 4)))
    eng = HSGD(model.loss, momentum(0.05), mk())
    # round fns donate their state argument: reuse goes via a host snapshot
    snap = jax.device_get(eng.init(jax.random.PRNGKey(0), model.init))
    fresh = lambda: jax.tree.map(jnp.asarray, snap)
    batches = tuple(batch_fn(ds)(t) for t in range(4))
    mask = np.array([1, 1, 0, 1, 1, 0, 1, 1], bool)
    ev = SyncEvent(level=1)
    dropped, _ = eng.round_fn(Round(4, ev), masked=True)(
        fresh(), batches, jnp.asarray(mask))
    nosync, _ = eng.round_fn(Round(4, None))(fresh(), batches)
    full, _ = eng.round_fn(Round(4, ev))(fresh(), batches)
    for tree_d, tree_n in ((dropped.params, nosync.params),
                           (dropped.opt_state, nosync.opt_state)):
        for d, n in zip(jax.tree.leaves(tree_d), jax.tree.leaves(tree_n)):
            np.testing.assert_array_equal(np.asarray(d)[~mask],
                                          np.asarray(n)[~mask])
    # admitted workers DID sync (and not to the unmasked aggregate)
    assert max_diff(jax.tree.map(lambda x: x[mask], dropped.params),
                    jax.tree.map(lambda x: x[mask], nosync.params)) > 0.0
    assert max_diff(jax.tree.map(lambda x: x[mask], dropped.params),
                    jax.tree.map(lambda x: x[mask], full.params)) > 0.0


def test_elastic_drop_contract_comms_residuals(setup):
    """Extends the PR-3 partial-participation tests: across a missed sync,
    a dropped worker ALSO keeps its unconsumed error-feedback residual
    bit-for-bit, while admitted workers' residuals are consumed/updated."""
    ds, model = setup
    mk = lambda: make_topology("uniform", spec=HierarchySpec((2, 4), (4, 4)))
    eng = HSGD(model.loss, sgd(0.05), mk(), comms=Comms("topk", rate=0.25))
    st = eng.init(jax.random.PRNGKey(0), model.init)
    # accumulate nonzero residuals first (two full rounds)
    st, _ = eng.run_rounds(st, batch_fn(ds), 8)
    assert max(float(jnp.abs(r).max()) for r in jax.tree.leaves(st.comms)) > 0
    old_res = [np.asarray(r).copy() for r in jax.tree.leaves(st.comms)]
    batches = tuple(batch_fn(ds)(t) for t in range(8, 12))
    mask = np.array([1, 0, 1, 1, 1, 1, 0, 1], bool)
    nxt, _ = eng.round_fn(Round(4, SyncEvent(level=1)), masked=True)(
        st, batches, jnp.asarray(mask))
    for r_new, r_old in zip(jax.tree.leaves(nxt.comms), old_res):
        np.testing.assert_array_equal(np.asarray(r_new)[~mask],
                                      r_old[~mask])
        assert float(np.abs(np.asarray(r_new)[mask] -
                            r_old[mask]).max()) > 0.0


def test_elastic_end_to_end_with_stragglers(setup):
    """run_rounds with a straggler regime + deadline: drops happen, the
    trajectory stays finite, elastic sim time <= full barrier per step
    (same seed = same draws), and a homogeneous fleet is untouched."""
    ds, model = setup
    mk = lambda: make_topology("uniform", spec=SPEC)

    def run(policy, straggler="fixed:0.25:6"):
        rt = RuntimeModel(compute_s=1.0, straggler=straggler, policy=policy,
                          seed=11)
        eng = HSGD(model.loss, sgd(0.05), mk(), runtime=rt,
                   comms=Comms("topk", rate=0.5))
        st = eng.init(jax.random.PRNGKey(0), model.init)
        st, hist = eng.run_rounds(st, batch_fn(ds), 16)
        return eng, st, hist

    eng_e, st_e, h_e = run(policy=1.0)
    eng_f, st_f, h_f = run(policy=None)
    assert eng_e.runtime_report()["dropped"][2] > 0
    assert all(np.isfinite(r["ce"]) for r in h_e)
    assert all(e["sim_time_s"] <= f["sim_time_s"] + 1e-9
               for e, f in zip(h_e, h_f))
    # no stragglers -> no drops -> bitwise the full-barrier trajectory
    eng_0, st_0, h_0 = run(policy=1.0, straggler=None)
    eng_1, st_1, h_1 = run(policy=None, straggler=None)
    assert eng_0.runtime_report()["dropped"] == {1: 0, 2: 0}
    assert max_diff(st_0.params, st_1.params) == 0.0
    assert [r["sim_time_s"] for r in h_0] == [r["sim_time_s"] for r in h_1]


def test_grouped_topology_runtime_end_to_end(setup):
    """Elastic runtime on a GroupedTopology with heterogeneous per-group
    periods: partial-group events and deadline drops compose."""
    ds, model = setup
    topo = GroupedTopology(contiguous(8, 2), G=8, I=(2, 4))
    rt = RuntimeModel(compute_s=1.0, straggler="lognormal:0.9",
                      policy=0.25, seed=4)
    eng = HSGD(model.loss, sgd(0.05), topo, runtime=rt)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    st, hist = eng.run_rounds(st, batch_fn(ds), 16)
    assert all(np.isfinite(r["ce"]) for r in hist)
    times = [r["sim_time_s"] for r in hist]
    assert all(a <= b for a, b in zip(times, times[1:]))
    assert sum(eng.runtime_report()["dropped"].values()) > 0


# ---------------------------------------------------------------------------
# planner fit
# ---------------------------------------------------------------------------
def test_comm_model_fit_from_trace(setup):
    """On a homogeneous full-barrier run the clock IS the CommModel closed
    form, so the least-squares fit recovers the constants exactly and
    wall_clock() reproduces the simulated makespan."""
    ds, model = setup
    topo = make_topology("uniform", spec=SPEC)
    links = (LinkModel(2.0, 1e8), LinkModel(0.1, 1e9))
    rt = RuntimeModel(compute_s=0.5, links=links)
    eng = HSGD(model.loss, sgd(0.05), topo, runtime=rt)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    st, hist = eng.run_rounds(st, batch_fn(ds), 32)
    fit = CommModel.fit_from_trace(hist, topo)
    clock = rt.clock(topo, eng._payload_nbytes(st))
    assert fit.compute_s == pytest.approx(0.5, rel=1e-6)
    assert fit.global_round_s == pytest.approx(clock.event_cost_s(1), rel=1e-6)
    assert fit.local_round_s == pytest.approx(clock.event_cost_s(2), rel=1e-6)
    assert fit.wall_clock(32, G=8, I=2) == pytest.approx(
        hist[-1]["sim_time_s"], rel=1e-6)
    # a RESUMED trace (absolute t > 0, per-call clock restarting at 0) must
    # fit the same constants: steps/events are regressed relative to the
    # trace's own start
    st, hist2 = eng.run_rounds(st, batch_fn(ds), 32)
    assert hist2[0]["t"] == 33
    fit2 = CommModel.fit_from_trace(hist2, topo)
    assert fit2.compute_s == pytest.approx(0.5, rel=1e-6)
    assert fit2.global_round_s == pytest.approx(fit.global_round_s, rel=1e-6)
    assert fit2.local_round_s == pytest.approx(fit.local_round_s, rel=1e-6)
    with pytest.raises(AssertionError, match="sim_time_s"):
        CommModel.fit_from_trace([{"t": 1}], topo)
