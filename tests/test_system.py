"""End-to-end behaviour tests: the paper's claims on live training runs
(CPU scale), plus the train/serve drivers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (HSGD, GroupedTopology, HierarchySpec, UniformTopology,
                        group_iid, group_noniid, local_sgd, two_level)
from repro.data import FederatedDataset, label_shard_partition, make_classification
from repro.models import SimpleConfig, SimpleModel
from repro.optim import sgd

N = 8


@pytest.fixture(scope="module")
def world():
    x, y = make_classification(3, num_classes=8, dim=24, per_class=80,
                               spread=1.5)
    parts = label_shard_partition(y, [[j] for j in range(8)])
    ds = FederatedDataset(x, y, parts)
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=24, hidden=32,
                                     num_classes=8))
    return ds, model


def train(model, ds, topology, T, lr=0.08, seed=0, bs=10):
    eng = HSGD(model.loss, sgd(lr), topology, jit=True)
    st = eng.init(jax.random.PRNGKey(seed), model.init)
    for t in range(T):
        st, _ = eng.step(st, jax.tree.map(jnp.asarray, ds.batch(t, bs)))
    gb = jax.tree.map(jnp.asarray, ds.global_batch(640))
    wbar = eng.mean_params(st)
    return float(model.loss(wbar, gb)[0]), float(model.accuracy(wbar, gb))


def test_sandwich_behavior_live(world):
    """Fig 3a: H-SGD(G, I) ends between local SGD P=I and P=G.
    Averaged over seeds to tame SGD noise."""
    ds, model = world
    T, G, I = 48, 16, 4
    losses = {"PI": [], "H": [], "PG": []}
    for seed in range(3):
        losses["PI"].append(train(model, ds, UniformTopology(local_sgd(N, I)),
                                  T, seed=seed)[0])
        losses["H"].append(train(model, ds,
                                 UniformTopology(two_level(N, 2, G, I)),
                                 T, seed=seed)[0])
        losses["PG"].append(train(model, ds, UniformTopology(local_sgd(N, G)),
                                  T, seed=seed)[0])
    pi, h, pg = (np.mean(losses[k]) for k in ("PI", "H", "PG"))
    assert pi <= h + 0.02, (pi, h, pg)
    assert h <= pg + 0.02, (pi, h, pg)


def test_group_iid_beats_group_noniid():
    """Fig 3c: grouping with small upward divergence converges better.
    World: 4 classes over 8 workers so a label-balanced grouping exists."""
    x, y = make_classification(3, num_classes=4, dim=24, per_class=160,
                               spread=1.5)
    parts = label_shard_partition(y, [[j % 4] for j in range(8)])
    ds = FederatedDataset(x, y, parts)
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=24, hidden=32,
                                     num_classes=4))
    labels = ds.dominant_labels()
    T, G, I = 48, 16, 4
    diffs = []
    for seed in range(3):
        l_iid = train(model, ds,
                      GroupedTopology(group_iid(labels, 2), G=G, I=I),
                      T, seed=seed)[0]
        l_non = train(model, ds,
                      GroupedTopology(group_noniid(labels, 2), G=G, I=I),
                      T, seed=seed)[0]
        diffs.append(l_non - l_iid)
    assert np.mean(diffs) > -0.02, diffs


def test_train_driver_smoke(tmp_path):
    from repro.launch.train import main
    hist = main(["--arch", "qwen2-0.5b", "--reduced", "--workers", "4",
                 "--groups", "2", "--G", "4", "--I", "2", "--steps", "12",
                 "--batch", "2", "--seq", "32", "--log-every", "4",
                 "--ckpt-dir", str(tmp_path), "--ckpt-every", "6"])
    assert hist[-1]["step"] == 12
    assert np.isfinite(hist[-1]["loss"])
    # loss decreases on the learnable synthetic stream
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.05
    # resume from checkpoint
    hist2 = main(["--arch", "qwen2-0.5b", "--reduced", "--workers", "4",
                  "--groups", "2", "--G", "4", "--I", "2", "--steps", "14",
                  "--batch", "2", "--seq", "32", "--log-every", "2",
                  "--ckpt-dir", str(tmp_path)])
    assert hist2[-1]["step"] == 14


def test_serve_driver_smoke():
    from repro.launch.serve import main
    res = main(["--arch", "mamba2-130m", "--reduced", "--batch", "2",
                "--prompt-len", "8", "--gen", "4"])
    assert res.tokens.shape == (2, 4)


def test_multilevel_driver_smoke():
    from repro.launch.train import main
    hist = main(["--arch", "mamba2-130m", "--reduced",
                 "--levels", "2,2,2:8,4,2", "--steps", "8", "--batch", "2",
                 "--seq", "16", "--log-every", "8", "--comms", "int8"])
    assert hist[-1]["step"] == 8
    assert np.isfinite(hist[-1]["loss"])
    # comms on: cumulative wire accounting rides the telemetry records, and
    # 8 steps of (8,4,2) hit L3 twice, L2 once, L1 once
    assert hist[-1]["wire_cum_bytes"] > 0
