"""Population regime: hierarchical virtual-client sampling, hydrate/fold-back,
the Participation protocol, and the EngineConfig consolidation.

The contract tests: a sampled round with k = n = population and uniform
weights is BITWISE the full-participation engine (params and opt state, on
both executors); weighted fold-back matches a numpy host oracle; empty-cell
draws hit the zero-denominator guard, never NaN; and nothing of population
size is ever materialized.

Mesh tests need 8 devices (ci.yml:
``XLA_FLAGS=--xla_force_host_platform_device_count=8``); they skip without.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EngineConfig, HSGD, HierarchySpec, MeshExecutor,
                        make_topology)
from repro.data import PopulationShards
from repro.models import SimpleConfig, SimpleModel
from repro.optim import adam, sgd
from repro.population import (ComposedParticipation, FullParticipation,
                              HierarchicalSampler, Population,
                              SampledParticipation, StaticParticipation,
                              compose, make_population)

needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices: export XLA_FLAGS="
           "--xla_force_host_platform_device_count=8 before jax init")

GS, PERIODS = (2, 4), (4, 2)   # k = 8 slots, G = 4 steps per sampling round
DIM, CLASSES = 12, 6


@pytest.fixture(scope="module")
def model():
    return SimpleModel(SimpleConfig(kind="mlp", input_dim=DIM, hidden=16,
                                    num_classes=CLASSES))


@pytest.fixture(scope="module")
def shards():
    return PopulationShards(population=8, num_classes=CLASSES, dim=DIM,
                            seed=5)


def tree_equal(a, b):
    return all(jax.tree.leaves(jax.tree.map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b)))


def topo():
    return make_topology("uniform", spec=HierarchySpec(GS, PERIODS))


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------
def test_draw_pure_and_sorted():
    s = HierarchicalSampler(Population(cells=(50, 40), seed=9), GS)
    d1, d2 = s.draw(3), s.draw(3)
    np.testing.assert_array_equal(d1.client_ids, d2.client_ids)
    np.testing.assert_array_equal(d1.paths, d2.paths)
    assert not np.array_equal(d1.client_ids, s.draw(4).client_ids)
    # cell-major static layout: top-level cell indices sorted, 4 slots each
    assert (np.diff(d1.paths[:, 0].reshape(2, 4), axis=1) == 0).all()
    assert d1.paths[0, 0] < d1.paths[4, 0]
    assert d1.k == 8 and d1.num_cells() == 2
    # Theorem-2 regrouping: slot-side grouping is the contiguous 2x4
    assert d1.grouping().assignment == (0,) * 4 + (1,) * 4


def test_draw_identity_when_k_equals_population():
    s = HierarchicalSampler(Population(cells=GS, seed=0), GS)
    for r in range(3):
        np.testing.assert_array_equal(s.draw(r).client_ids, np.arange(8))


def test_draw_seeds_independent():
    a = HierarchicalSampler(Population(cells=(100, 100), seed=1), GS)
    b = HierarchicalSampler(Population(cells=(100, 100), seed=2), GS)
    assert not np.array_equal(a.draw(0).client_ids, b.draw(0).client_ids)


def test_sampler_validation():
    with pytest.raises(ValueError, match="one fanout per"):
        HierarchicalSampler(Population(cells=(100,)), GS)
    with pytest.raises(ValueError, match="must be >="):
        HierarchicalSampler(Population(cells=(100, 2)), GS)


def test_availability_marks_empty_slots():
    pop = Population(cells=(100, 100), seed=3, p_available=0.5)
    s = HierarchicalSampler(pop, GS)
    draws = [s.draw(r) for r in range(20)]
    active = np.concatenate([d.active for d in draws])
    assert 0.25 < active.mean() < 0.75
    d0 = draws[0]
    np.testing.assert_array_equal(d0.client_ids, s.draw(0).client_ids)
    assert (d0.client_ids[~d0.active] == -1).all()


def test_make_population():
    assert make_population(None) is None
    p = Population(cells=(4, 2))
    assert make_population(p) is p
    assert make_population((10, 20)).cells == (10, 20)
    assert make_population(16).cells == (16,)
    assert make_population((10, 20)).size == 200
    with pytest.raises(TypeError):
        make_population("millions")


# ---------------------------------------------------------------------------
# bitwise: k = n = population, uniform weights == full participation
# ---------------------------------------------------------------------------
def _bitwise_check(model, shards, optimizer, executor=None, rounds=3):
    batch = lambda t: jax.tree.map(jnp.asarray,
                                   shards.batch(np.arange(8), t, 6))
    T = rounds * PERIODS[0]

    base = HSGD(model.loss, optimizer(), topo(),
                EngineConfig(executor=executor() if executor else None))
    st = base.init(jax.random.PRNGKey(0), model.init)
    st, _ = base.run_rounds(st, batch, T)

    pop = HSGD(model.loss, optimizer(), topo(), EngineConfig(
        executor=executor() if executor else None,
        population=Population(cells=GS, seed=0)))
    server = pop.init_server(jax.random.PRNGKey(0), model.init)
    server, hist = pop.run_sampled(
        server, lambda ids, t: batch(t), rounds)

    st = jax.device_get(st)
    row0 = jax.tree.map(lambda x: x[0], (st.params, st.opt_state))
    assert tree_equal(row0[0], server.params)
    assert tree_equal(row0[1], server.opt_state)
    assert hist[-1]["participation"]["unique"] == 8  # identity redraws
    return hist


def test_bitwise_full_participation_sim_sgd(model, shards):
    hist = _bitwise_check(model, shards, lambda: sgd(0.1))
    assert [h["round"] for h in hist] == [1, 2, 3]


def test_bitwise_full_participation_sim_adam(model, shards):
    # opt-state moments take the fold-back's dense path
    _bitwise_check(model, shards, lambda: adam(3e-3))


@needs_devices
def test_bitwise_full_participation_mesh(model, shards):
    from repro.launch.mesh import make_host_mesh
    # exact=True is the repo's bitwise mesh ladder: mesh == sim == fold-back
    ex = lambda: MeshExecutor(make_host_mesh(group_sizes=GS), exact=True)
    _bitwise_check(model, shards, lambda: sgd(0.1), executor=ex, rounds=2)


# ---------------------------------------------------------------------------
# fold-back vs host oracle
# ---------------------------------------------------------------------------
def test_weighted_fold_matches_host_oracle(model, shards):
    eng = HSGD(model.loss, sgd(0.1), topo(), EngineConfig(
        population=Population(cells=GS, seed=0, weighting="size")))
    popeng = eng.population_engine()
    server = eng.init_server(jax.random.PRNGKey(1), model.init)
    batch = lambda ids, t: jax.tree.map(jnp.asarray,
                                        shards.batch(ids, t, 6))
    sizes = shards.size_fn()
    # run the inner round by hand to capture the pre-fold slot params
    draw = popeng.sampler.draw(0)
    state = popeng.hydrate(server)
    state, _ = popeng.inner.run_rounds(
        state, lambda t: batch(draw.client_ids, t), PERIODS[0])
    w, meta = popeng.round_weights(draw, sizes)
    assert meta["active"] == 8
    np.testing.assert_allclose(
        w, [sizes(int(c)) for c in draw.client_ids])
    folded = popeng.fold_back(server, state, w)
    p = jax.device_get(state.params)
    oracle = jax.tree.map(
        lambda x: np.average(np.asarray(x, np.float64), axis=0, weights=w),
        p)
    for got, want in zip(jax.tree.leaves(folded.params),
                         jax.tree.leaves(oracle)):
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-6,
                                   atol=1e-7)


def test_nonzero_fold_guard_and_oracle(model):
    eng = HSGD(model.loss, sgd(0.1), topo(), EngineConfig(
        population=Population(cells=GS, fold="nonzero")))
    popeng = eng.population_engine()
    assert popeng.fold_mode == "nonzero"
    server = eng.init_server(jax.random.PRNGKey(2), model.init)
    state = popeng.hydrate(server)
    # slot j moves only entries with (flat index % 8) == j — sparse-codec
    # shape deltas; entries 0-5 move (weighted slots), 6-7 stay untouched
    w = np.array([1.0, 2.0, 3.0, 4.0, 1.0, 1.0, 0.0, 0.0])

    def perturb(x):
        dt = x.dtype
        x = np.asarray(x, np.float64)
        idx = np.arange(x[0].size).reshape(x.shape[1:]) % 8
        return jnp.asarray(np.stack([
            x[j] + (idx == j) * (0.5 + j) for j in range(8)]), dt)

    state = dataclasses.replace(state,
                                params=jax.tree.map(perturb, state.params))
    folded = popeng.fold_back(server, state, w)
    for s, got in zip(jax.tree.leaves(server.params),
                      jax.tree.leaves(folded.params)):
        s, got = np.asarray(s, np.float64), np.asarray(got, np.float64)
        idx = np.arange(s.size).reshape(s.shape) % 8
        delta = got - s
        assert np.isfinite(got).all()
        for j in range(8):
            sel = idx == j
            if not sel.any():
                continue
            if w[j] > 0:
                # only slot j moved these entries: weighted mean of one
                # contributor is its own delta
                np.testing.assert_allclose(delta[sel], 0.5 + j, rtol=1e-5)
            else:
                # zero total weight -> denominator floor -> server value
                np.testing.assert_allclose(delta[sel], 0.0, atol=1e-12)


def test_all_empty_round_keeps_server_bitwise(model, shards):
    eng = HSGD(model.loss, sgd(0.1), topo(), EngineConfig(
        population=Population(cells=(100, 100), seed=0, p_available=0.0)))
    server = eng.init_server(jax.random.PRNGKey(3), model.init)
    p0 = jax.tree.map(np.asarray, server.params)
    batch = lambda ids, t: jax.tree.map(jnp.asarray,
                                        shards.batch(ids % 8, t, 6))
    server, hist = eng.run_sampled(server, batch, 2)
    assert tree_equal(p0, server.params)
    assert all(np.isfinite(x).all() for x in jax.tree.leaves(
        jax.tree.map(np.asarray, server.params)))
    assert hist[0]["participation"]["active"] == 0


def test_partial_availability_trains_finite(model, shards):
    eng = HSGD(model.loss, sgd(0.1), topo(), EngineConfig(
        population=Population(cells=(100, 100), seed=1, p_available=0.6,
                              weighting="size")))
    server = eng.init_server(jax.random.PRNGKey(4), model.init)
    batch = lambda ids, t: jax.tree.map(jnp.asarray,
                                        shards.batch(ids % 8, t, 6))
    server, hist = eng.run_sampled(server, batch, 4)
    assert all(np.isfinite(x).all() for x in jax.tree.leaves(
        jax.tree.map(np.asarray, server.params)))
    acts = [h["participation"]["active"] for h in hist]
    assert min(acts) >= 0 and max(acts) <= 8 and sum(acts) > 0


# ---------------------------------------------------------------------------
# population scale: memory bounded by k, auditor clean
# ---------------------------------------------------------------------------
def test_million_client_state_bounded_by_k(model):
    shards = PopulationShards(population=10**6, num_classes=CLASSES,
                              dim=DIM, seed=7)
    eng = HSGD(model.loss, sgd(0.1), topo(), EngineConfig(
        population=Population(cells=(1000, 1000), seed=7)))
    assert eng.population.size == 10**6
    server = eng.init_server(jax.random.PRNGKey(0), model.init)
    popeng = eng.population_engine()
    state = popeng.hydrate(server)
    k = 8
    for leaf in jax.tree.leaves((state.params, state.opt_state)):
        assert leaf.shape[0] == k
        assert leaf.size <= k * 10_000  # nothing population-sized
    draw = popeng.sampler.draw(0)
    assert draw.client_ids.size == k and draw.client_ids.max() < 10**6
    batch = lambda ids, t: jax.tree.map(jnp.asarray, shards.batch(ids, t, 6))
    server, hist = eng.run_sampled(server, batch, 2, sizes=shards.size_fn())
    assert all(np.isfinite(x).all() for x in jax.tree.leaves(
        jax.tree.map(np.asarray, server.params)))
    p = hist[-1]["participation"]
    assert p["population"] == 10**6 and p["k"] == 8
    # ledger grows with sampled clients, not the population
    assert len(server.ledger.counts) <= 16


def test_audit_clean_on_sampled_round_body(model, shards):
    eng = HSGD(model.loss, sgd(0.1), topo(), EngineConfig(
        population=Population(cells=(1000, 1000), seed=7)))
    server = eng.init_server(jax.random.PRNGKey(0), model.init)
    batch = lambda ids, t: jax.tree.map(jnp.asarray,
                                        shards.batch(ids % 8, t, 6))
    report = eng.population_engine().audit(server, batch, config="pop/sim")
    assert not report.unwaived, report.summary()
    # the sampled round body defers level 1 to the fold-back: the audited
    # schedule must fire only sub-global events
    assert all(not key.startswith("L1") for key in report.events)


# ---------------------------------------------------------------------------
# Participation protocol
# ---------------------------------------------------------------------------
def test_participation_protocol_composes():
    t = topo()
    from repro.core.topology import SyncEvent
    ev = SyncEvent(level=2)
    static = StaticParticipation(t)
    # a uniform topology restricts nothing: every hook is "no restriction"
    assert static.event_mask(ev) is None
    assert static.round_mask(ev) is None
    assert FullParticipation().event_mask(ev) is None

    pop = Population(cells=(100, 100), seed=3, p_available=0.5)
    sampled = SampledParticipation(pop, GS, round_index=0)
    draw = HierarchicalSampler(pop, GS).draw(0)
    assert not draw.active.all()  # seed 3 @ p=0.5 has empty slots
    np.testing.assert_array_equal(sampled.round_mask(ev), draw.active)
    assert sampled.draw(0).round_index == 0  # pinned

    composed = compose(static, None, sampled)
    assert isinstance(composed, ComposedParticipation)
    # AND of masks: the only restriction is the sampler's availability
    np.testing.assert_array_equal(composed.round_mask(ev), draw.active)
    assert composed.event_mask(ev) is None
    assert composed.draw(0).round_index == 0
    # single member: compose collapses to it; none: the identity element
    assert compose(static, None) is static
    assert isinstance(compose(None, None), FullParticipation)
    assert t.participation().topology is t


# ---------------------------------------------------------------------------
# EngineConfig consolidation + deprecation shim
# ---------------------------------------------------------------------------
def test_engineconfig_shim_warns_and_matches(model, shards):
    batch = lambda t: jax.tree.map(jnp.asarray,
                                   shards.batch(np.arange(8), t, 6))
    new = HSGD(model.loss, sgd(0.1), topo(), EngineConfig(executor="sim"))
    with pytest.warns(DeprecationWarning, match="executor=..."):
        old = HSGD(model.loss, sgd(0.1), topo(), executor="sim")
    assert old.config == new.config
    s1 = new.init(jax.random.PRNGKey(0), model.init)
    s2 = old.init(jax.random.PRNGKey(0), model.init)
    s1, _ = new.run_rounds(s1, batch, 4)
    s2, _ = old.run_rounds(s2, batch, 4)
    assert tree_equal(s1.params, s2.params)


def test_engineconfig_scalar_kwargs_fold_silently(model):
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng = HSGD(model.loss, sgd(0.1), topo(), jit=False, accum_steps=2)
    assert eng.config == EngineConfig(jit=False, accum_steps=2)


def test_engineconfig_rejects_mixing(model):
    with pytest.raises(TypeError, match="both config="):
        HSGD(model.loss, sgd(0.1), topo(), EngineConfig(), comms="int8")


def test_engineconfig_describe_roundtrips():
    import json
    cfg = EngineConfig(executor="sim", comms=None,
                       population=Population(cells=(10, 10)))
    d = json.loads(json.dumps(cfg.describe()))
    assert d["executor"] == "sim"
    assert d["population"]["cells"] == [10, 10]
    assert d["jit"] is True


def test_run_sampled_requires_population(model):
    eng = HSGD(model.loss, sgd(0.1), topo())
    with pytest.raises(ValueError, match="no population bound"):
        eng.init_server(jax.random.PRNGKey(0), model.init)
