"""Model-level correctness: prefill+decode == full forward for every family,
masking semantics, RoPE properties, GQA equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.layers as L
from repro.configs import ARCH_IDS, all_configs, reduced
from repro.models import build_model

CONFIGS = all_configs()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch, rng):
    cfg = reduced(CONFIGS[arch])
    model = build_model(cfg)
    params = model.init(rng)
    B, S, P = 2, 12, 9
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_inputs"] = jax.random.normal(rng, (B, 4, cfg.d_model),
                                             dtype=jnp.float32)
        full, _ = model.forward(params, toks, kw["enc_inputs"])
    else:
        full, _ = model.forward(params, toks)
    lg, cache = model.prefill(params, toks[:, :P], max_len=S, **kw)
    errs = [np.abs(np.asarray(lg) - np.asarray(full[:, P - 1])).max()]
    for t in range(P, S):
        lg, cache = model.decode_step(params, cache, toks[:, t])
        errs.append(np.abs(np.asarray(lg) - np.asarray(full[:, t])).max())
    assert max(errs) < 1e-4, (arch, errs)


def test_causal_mask_window():
    m = L.causal_mask(6, 6, window=3)
    m = np.asarray(m)
    for i in range(6):
        for j in range(6):
            assert m[i, j] == (j <= i and i - j < 3)


def test_rope_preserves_norm_and_relative_phase(rng):
    x = jax.random.normal(rng, (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    y = L.apply_rope(x, pos, 10_000.0)
    # rotation preserves per-head vector norm
    nx = np.linalg.norm(np.asarray(x), axis=-1)
    ny = np.linalg.norm(np.asarray(y), axis=-1)
    np.testing.assert_allclose(nx, ny, rtol=1e-5)
    # dot products depend only on relative distance
    q = jax.random.normal(rng, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 1, 1, 16))
    def score(pq, pk):
        qq = L.apply_rope(q, jnp.full((1, 1), pq), 10_000.0)
        kk = L.apply_rope(k, jnp.full((1, 1), pk), 10_000.0)
        return float(jnp.sum(qq * kk))
    assert abs(score(3, 1) - score(7, 5)) < 1e-4


def test_gqa_equals_repeated_mha(rng):
    """GQA with kv repeated == full attention with explicitly repeated k/v."""
    cfg = reduced(CONFIGS["qwen2-0.5b"])
    p = L.attention_init(rng, cfg)
    x = jax.random.normal(rng, (2, 8, cfg.d_model), dtype=jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    out = L.attention_apply(p, x, cfg, positions=pos)
    # manual: repeat kv heads into an MHA-equivalent config
    cfg_mha = dataclasses.replace(cfg, num_kv_heads=cfg.num_heads)
    wk = jnp.concatenate([jnp.repeat(w, cfg.num_heads // cfg.num_kv_heads, axis=1)
                          for w in [p["wk"].reshape(cfg.d_model, cfg.num_kv_heads,
                                                    cfg.d_head)]], axis=0)
    p2 = dict(p)
    p2["wk"] = jnp.repeat(p["wk"].reshape(cfg.d_model, cfg.num_kv_heads,
                                          cfg.d_head),
                          cfg.num_heads // cfg.num_kv_heads,
                          axis=1).reshape(cfg.d_model, -1)
    p2["wv"] = jnp.repeat(p["wv"].reshape(cfg.d_model, cfg.num_kv_heads,
                                          cfg.d_head),
                          cfg.num_heads // cfg.num_kv_heads,
                          axis=1).reshape(cfg.d_model, -1)
    p2["bk"] = jnp.repeat(p["bk"].reshape(cfg.num_kv_heads, cfg.d_head),
                          cfg.num_heads // cfg.num_kv_heads, axis=0).reshape(-1)
    p2["bv"] = jnp.repeat(p["bv"].reshape(cfg.num_kv_heads, cfg.d_head),
                          cfg.num_heads // cfg.num_kv_heads, axis=0).reshape(-1)
    out2 = L.attention_apply(p2, x, cfg_mha, positions=pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               atol=2e-5, rtol=1e-4)


def test_chunked_attention_matches_dense(rng):
    q = jax.random.normal(rng, (2, 1024, 4, 16))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 1024, 4, 16))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 1024, 4, 16))
    mask = L.causal_mask(1024, 1024, window=64)
    dense = L._attn_core_dense(q, k, v, mask, None)
    chunk = L._attn_core_chunked(q, k, v, mask, None, 256)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunk),
                               atol=2e-5, rtol=1e-4)
    # gradients too (checkpointed body)
    g1 = jax.grad(lambda q: L._attn_core_dense(q, k, v, mask, None).sum())(q)
    g2 = jax.grad(lambda q: L._attn_core_chunked(q, k, v, mask, None, 256).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=2e-4, rtol=1e-3)


def test_moe_group_capacity_flops_bound(rng):
    """Group-chunked MoE equals single-group MoE when no tokens drop."""
    cfg = reduced(CONFIGS["mixtral-8x22b"])
    p = L.moe_init(rng, cfg)
    x = jax.random.normal(rng, (2, 8, cfg.d_model), dtype=jnp.float32)
    y1, a1 = L.moe_apply(p, x, cfg)
    # same computation via the internal group fn directly
    y2, a2 = L._moe_group(p, x.reshape(16, cfg.d_model), cfg)
    np.testing.assert_allclose(np.asarray(y1).reshape(16, -1), np.asarray(y2),
                               atol=1e-5, rtol=1e-4)


def test_sliding_window_blocks_far_tokens(rng):
    """With window=2, changing token 0 must not affect outputs at pos >= 4
    in a single local-attention layer."""
    cfg = dataclasses.replace(reduced(CONFIGS["gemma3-12b"]),
                              block_pattern=("local",), num_layers=1,
                              sliding_window=2)
    from repro.models.transformer import block_apply, block_init
    p = block_init(rng, "local", cfg)
    x = jax.random.normal(rng, (1, 8, cfg.d_model), dtype=jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    y1, _ = block_apply(p, x, "local", cfg, positions=pos)
    x2 = x.at[0, 0].add(1.0)
    y2, _ = block_apply(p, x2, "local", cfg, positions=pos)
    # positions >= 2 cannot see token 0 (window=2 means j > i-2)
    np.testing.assert_allclose(np.asarray(y1[0, 2:]), np.asarray(y2[0, 2:]),
                               atol=1e-5)
    assert np.abs(np.asarray(y1[0, 0]) - np.asarray(y2[0, 0])).max() > 1e-3
