"""Per-architecture smoke tests: a REDUCED same-family variant runs one
forward/train step on CPU; output shapes + finiteness asserted.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_configs, get_config, reduced
from repro.models import build_model
from repro.optim import sgd

CONFIGS = all_configs()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_constraints(arch):
    cfg = reduced(CONFIGS[arch])
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.num_layers <= max(3, len(cfg.block_pattern))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = reduced(CONFIGS[arch])
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 16
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    if cfg.family == "encdec":
        enc = jax.random.normal(rng, (B, 4, cfg.d_model), dtype=jnp.float32)
        logits, aux = model.forward(params, toks, enc)
    else:
        logits, aux = model.forward(params, toks)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_reduces_loss_direction(arch, rng):
    """One SGD step on a fixed batch must not produce NaNs and must change
    params; loss on the same batch should not increase (small lr)."""
    cfg = reduced(CONFIGS[arch])
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 16
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["enc_inputs"] = jax.random.normal(
            rng, (B, 4, cfg.d_model), dtype=jnp.float32)
    opt = sgd(1e-2)
    ostate = opt.init(params)
    loss0, _ = model.loss(params, batch)
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    upd, ostate = opt.update(grads, ostate, params)
    params2 = jax.tree.map(jnp.add, params, upd)
    loss1, _ = model.loss(params2, batch)
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss1))
    assert float(loss1) <= float(loss0) + 1e-3, (float(loss0), float(loss1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_smoke(arch, rng):
    cfg = reduced(CONFIGS[arch])
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 8
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_inputs"] = jax.random.normal(rng, (B, 4, cfg.d_model),
                                             dtype=jnp.float32)
    lg, cache = model.prefill(params, toks, max_len=S + 2, **kw)
    assert lg.shape == (B, cfg.vocab_size)
    lg2, cache = model.decode_step(params, cache, jnp.argmax(lg, -1).astype(jnp.int32))
    assert lg2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()


def test_exact_assigned_numbers():
    """The full configs carry the exact assigned architecture numbers."""
    c = get_config("nemotron-4-340b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (96, 18432, 96, 8, 73728, 256000)
    assert c.mlp_variant == "relu2"
    c = get_config("qwen2-0.5b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (24, 896, 14, 2, 4864, 151936)
    assert c.qkv_bias
    c = get_config("gemma3-12b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (48, 3840, 16, 8, 15360, 262144)
    assert c.block_pattern.count("local") == 5 and \
        c.block_pattern.count("global") == 1
    c = get_config("recurrentgemma-2b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (26, 2560, 10, 1, 7680, 256000)
    c = get_config("seamless-m4t-large-v2")
    assert (c.num_layers, c.num_encoder_layers, c.d_model, c.num_heads,
            c.d_ff, c.vocab_size) == (24, 24, 1024, 16, 8192, 256206)
    c = get_config("phi3-mini-3.8b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (32, 3072, 32, 32, 8192, 32064)
    c = get_config("mamba2-130m")
    assert (c.num_layers, c.d_model, c.vocab_size, c.ssm_state) == \
        (24, 768, 50280, 128)
    assert c.d_ff == 0 and c.block_pattern == ("ssd",)
    c = get_config("chameleon-34b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (48, 8192, 64, 8, 22016, 65536)
    c = get_config("mixtral-8x22b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (56, 6144, 48, 8, 16384, 32768)
    assert (c.num_experts, c.num_experts_per_tok) == (8, 2)
    assert c.sliding_window == 4096
    c = get_config("olmoe-1b-7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.moe_d_ff,
            c.vocab_size) == (16, 2048, 16, 1024, 50304)
    assert (c.num_experts, c.num_experts_per_tok) == (64, 8)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_analytic_close(arch, rng):
    """Analytic param_count (used for roofline MODEL_FLOPS) matches the real
    reduced pytree within 1.5%."""
    cfg = reduced(CONFIGS[arch])
    model = build_model(cfg)
    params = model.init(rng)
    actual = sum(x.size for x in jax.tree.leaves(params))
    assert abs(actual - cfg.param_count()) / actual < 0.015
