"""repro.analysis: the walker, the rule catalog (R1–R5) on hand-built
report fixtures AND live engines, the budget diff, and the CLI gate.

Every rule gets a good/bad fixture pair built from plain report data (no
tracers), plus a live demonstration where one device suffices: an injected
extra reduction is caught by R1, the legacy int8 encode→reduce(f32)→decode
roundtrip (``wire_reduce=False``) fires R2 while the default compressed
collective is clean, a
``jax.debug.print`` smuggled into the loss is caught by R3, and synthetic
budget regressions (extra sync op, dtype upcast, byte growth) fail the
check — the acceptance criteria of the analysis subsystem.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (EventAudit, Finding, RoundAudit, SyncPlanReport,
                            audit_engine, check_reports, entry_from_report,
                            fingerprint, run_rules, trace, update_budget,
                            walk, waivers_for)
from repro.analysis.__main__ import CONFIGS, build_engine, main
from repro.core.hsgd import HSGD
from repro.core.topology import HierarchySpec, make_topology
from repro.models.simple import SimpleConfig, SimpleModel
from repro.optim.optimizers import sgd


# ---------------------------------------------------------------------------
# walker
# ---------------------------------------------------------------------------
def test_walker_records_collectives_with_axes_and_payload():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    f = shard_map(lambda v: jax.lax.psum(v, "x"), mesh=mesh,
                  in_specs=P("x"), out_specs=P(), check_rep=False)
    summary = trace(jax.jit(f), jnp.ones((1, 4), jnp.float32))
    assert summary.collective_count == 1
    op = summary.collectives[0]
    assert op.primitive in ("psum", "psum2")
    assert op.axes == ("x",)
    assert op.dtypes == ("float32",)
    assert op.elements == 4 and op.nbytes == 16
    assert "shard_map" in op.path  # nested walk records the enclosure


def test_walker_records_host_callbacks():
    def g(x):
        jax.debug.print("sum={s}", s=x.sum())
        return x * 2

    summary = trace(g, jnp.ones(3))
    assert [o.primitive for o in summary.callbacks] == ["debug_callback"]


def test_walker_descends_into_scan_bodies():
    def f(x):
        def body(c, _):
            return c + x.sum(), None
        out, _ = jax.lax.scan(body, 0.0, jnp.arange(3.0))
        return out

    summary = trace(f, jnp.ones(4))
    assert any(o.primitive == "reduce_sum" and o.path.startswith("scan")
               for o in summary.reduces)


def test_fingerprint_stable_across_traces_and_sensitive_to_program():
    # grad-of-relu carries custom_jvp_call params whose pretty-print embeds
    # function object addresses — the fingerprint must scrub them
    f = lambda x: jax.grad(lambda y: jax.nn.relu(y).sum())(x)
    j1 = jax.make_jaxpr(f)(jnp.ones(3))
    j2 = jax.make_jaxpr(f)(jnp.ones(3))
    assert fingerprint(j1) == fingerprint(j2)
    j3 = jax.make_jaxpr(lambda x: x * 3)(jnp.ones(3))
    assert fingerprint(j1) != fingerprint(j3)


# ---------------------------------------------------------------------------
# rule fixtures (plain report data, no tracing)
# ---------------------------------------------------------------------------
def mk_event(key="L1", sync_ops=6, expected=6, dtypes=("float32",),
             nbytes=976, elements=244, expected_elements=None, axes=()):
    return EventAudit(key=key, level=int(key[1]), groups=None,
                      sync_ops=sync_ops, expected_sync_ops=expected,
                      ops=(), axes=tuple(axes), wire_dtypes=tuple(dtypes),
                      payload_elements=elements, payload_bytes=nbytes,
                      expected_payload_elements=expected_elements)


def mk_round(key="r4+L1", collectives=0, callbacks=(), transfers=(),
             cache_stable=True, cache_size=1):
    return RoundAudit(key=key, n_local=4, event=key.split("+")[1],
                      collective_count=collectives,
                      callbacks=tuple(callbacks), transfers=tuple(transfers),
                      cache_stable=cache_stable, jit_cache_size=cache_size)


def mk_report(events=(), rounds=(), codec=None, wire=None, config="fixture",
              waivers=()):
    report = SyncPlanReport(
        config=config, executor="sim", topology="UniformTopology",
        aggregator="MeanAggregator", codec=codec,
        events={e.key: e for e in events},
        rounds={r.key: r for r in rounds}, wire=wire)
    return dataclasses.replace(
        report, findings=tuple(run_rules(report, waivers)))


def rules_fired(report):
    return sorted({f.rule for f in report.findings})


def test_r1_sync_op_count():
    assert rules_fired(mk_report(events=[mk_event()])) == []
    assert rules_fired(mk_report(events=[mk_event(sync_ops=7)])) == ["R1"]
    # no exact expectation -> R1 defers to the budget
    assert rules_fired(
        mk_report(events=[mk_event(sync_ops=7, expected=None)])) == []


def test_r2_fires_on_f32_reduction_under_compressing_codec():
    # the deliberately-upcast codec fixture: int8 codec, f32 on the wire
    bad = mk_report(events=[mk_event()], codec="int8")
    assert rules_fired(bad) == ["R2"] and not bad.findings[0].waived
    # identity / comms-off configs move f32 legitimately
    assert rules_fired(mk_report(events=[mk_event()], codec="identity")) == []
    assert rules_fired(mk_report(events=[mk_event()], codec=None)) == []
    # a codec that actually ships int8 would pass
    assert rules_fired(
        mk_report(events=[mk_event(dtypes=("int8",))], codec="int8")) == []


def test_r2_waiver_suppresses_but_keeps_the_finding_visible():
    waived = mk_report(events=[mk_event()], codec="int8",
                       waivers={"R2": "baseline until compressed allreduce"})
    assert waived.unwaived == ()
    (f,) = waived.findings
    assert f.rule == "R2" and f.waived and "baseline" in f.waive_reason


def test_r3_host_callbacks_and_transfers():
    assert rules_fired(mk_report(rounds=[mk_round()])) == []
    bad = mk_report(rounds=[mk_round(callbacks=("debug_callback@pjit/scan",))])
    assert rules_fired(bad) == ["R3"]
    assert "debug_callback" in bad.findings[0].message
    assert rules_fired(
        mk_report(rounds=[mk_round(transfers=("device_put@pjit",))])) == ["R3"]


def test_r4_retrace_detection():
    assert rules_fired(mk_report(rounds=[mk_round(cache_size=1)])) == []
    assert rules_fired(mk_report(rounds=[mk_round(cache_size=3)])) == ["R4"]
    assert rules_fired(
        mk_report(rounds=[mk_round(cache_stable=False)])) == ["R4"]
    # unmeasured (no run_rounds pass) is not a finding
    assert rules_fired(mk_report(rounds=[mk_round(cache_size=None)])) == []


def test_r5_wire_accounting_cross_check():
    assert rules_fired(
        mk_report(events=[mk_event(expected_elements=244)])) == []
    assert rules_fired(
        mk_report(events=[mk_event(expected_elements=250)])) == ["R5"]


def test_report_json_roundtrip():
    rep = mk_report(events=[mk_event(axes=("pod", "data"))],
                    rounds=[mk_round(callbacks=("debug_callback@scan",))],
                    codec="int8",
                    wire={"payload_bytes": 248, "n_elements": 244,
                          "f32_bytes": 976, "wire_dtypes": ["float32", "int8"]})
    back = SyncPlanReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert back == rep


# ---------------------------------------------------------------------------
# live audits (sim executor, 1 device)
# ---------------------------------------------------------------------------
def test_live_audit_sim_off_matches_schedule():
    eng, state, batch_fn = build_engine("sim/two_level/off")
    rep = eng.audit(state, batch_fn, config="sim/two_level/off")
    assert set(rep.events) == {"L1", "L2"}
    for ev in rep.events.values():
        assert ev.sync_ops == ev.expected_sync_ops == 6  # mlp leaves
    assert rep.unwaived == ()
    # one compiled variant per round signature across run_rounds (R4 clean)
    assert {r.jit_cache_size for r in rep.rounds.values()} == {1}
    assert {r.cache_stable for r in rep.rounds.values()} == {True}


def test_live_audit_int8_r2_burned_down_by_wire_reduce():
    """The compressed-collective lowering keeps int8 on the wire (one int32
    psum-in-wire-dtype per bucket), so R2 passes with NO waiver; forcing
    the legacy roundtrip (``wire_reduce=False``) still fires it — the rule
    watches the lowering, not the codec declaration."""
    eng, state, _ = build_engine("sim/two_level/int8")
    rep = eng.audit(state)  # sync-only audit: no batch_fn needed for R2
    assert rep.unwaived == ()
    for ev in rep.events.values():
        assert "float32" not in ev.wire_dtypes
        assert ev.f32_elements == 0

    from repro.comms import Comms
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=16, hidden=8,
                                     num_classes=4))
    topo = make_topology("uniform", spec=HierarchySpec((2, 4), (8, 4)))
    legacy = HSGD(model.loss, sgd(0.1), topo,
                  comms=Comms("int8", wire_reduce=False))
    lstate = legacy.init(jax.random.PRNGKey(0), model.init)
    lrep = legacy.audit(lstate)
    assert sorted({f.rule for f in lrep.unwaived}) == ["R2"]
    waived = legacy.audit(lstate, waivers={"R2": "known baseline"})
    assert waived.unwaived == ()
    assert any(f.rule == "R2" and f.waived for f in waived.findings)


def test_live_injected_extra_reduction_caught_by_r1():
    """The synthetic regression of the acceptance criteria: an executor
    that sneaks one extra per-leaf reduction into every sync is caught by
    R1 (sync-op count doubles against the schedule prediction)."""
    from repro.core.executors import SimExecutor

    class ExtraReduceExecutor(SimExecutor):
        def sync_fn(self, event):
            base = super().sync_fn(event)

            def sync(params, opt_state, cstate, mask=None):
                p, o, c = base(params, opt_state, cstate, mask=mask)
                p = jax.tree.map(lambda x: x + 0.0 * x.sum(0, keepdims=True),
                                 p)
                return p, o, c

            return sync

    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=16, hidden=8,
                                     num_classes=4))
    topo = make_topology("uniform", spec=HierarchySpec((2, 4), (8, 4)))
    eng = HSGD(model.loss, sgd(0.1), topo, executor=ExtraReduceExecutor())
    state = eng.init(jax.random.PRNGKey(0), model.init)
    rep = eng.audit(state)
    assert sorted({f.rule for f in rep.unwaived}) == ["R1"]
    assert all(ev.sync_ops == 2 * ev.expected_sync_ops
               for ev in rep.events.values())


def test_live_debug_print_in_loss_caught_by_r3():
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=16, hidden=8,
                                     num_classes=4))

    def noisy_loss(params, batch):
        loss, metrics = model.loss(params, batch)
        jax.debug.print("loss={l}", l=loss)
        return loss, metrics

    topo = make_topology("uniform", spec=HierarchySpec((2, 4), (8, 4)))
    eng = HSGD(noisy_loss, sgd(0.1), topo)
    state = eng.init(jax.random.PRNGKey(0), model.init)
    bf = lambda t: {"x": jnp.zeros((8, 4, 16), jnp.float32),
                    "y": jnp.zeros((8, 4), jnp.int32)}
    rep = audit_engine(eng, state, bf, run=False)  # trace only, no printing
    assert sorted({f.rule for f in rep.unwaived}) == ["R3"]
    assert any("debug_callback" in c
               for r in rep.rounds.values() for c in r.callbacks)


# ---------------------------------------------------------------------------
# budget gating
# ---------------------------------------------------------------------------
def budget_for(report):
    return {"version": 1, "waivers": {},
            "configs": {report.config: entry_from_report(report)}}


def test_budget_unchanged_report_passes():
    rep = mk_report(events=[mk_event()], rounds=[mk_round()])
    regs, imps = check_reports([rep], budget_for(rep))
    assert regs == [] and imps == []


@pytest.mark.parametrize("mutate, expect", [
    (lambda e: mk_event(sync_ops=7, expected=None), "sync ops grew"),
    (lambda e: mk_event(dtypes=("float32", "float64")), "new wire dtype"),
    (lambda e: mk_event(nbytes=1952), "payload bytes grew"),
    (lambda e: mk_event(axes=("pod",)), "named axes changed"),
])
def test_budget_catches_synthetic_regressions(mutate, expect):
    """Extra sync op / f32->f64 upcast / byte growth / axis change injected
    over the pinned baseline all fail the check."""
    base = mk_report(events=[mk_event(axes=())])
    budget = budget_for(base)
    bad = mk_report(events=[mutate(None)])
    regs, _ = check_reports([bad], budget)
    assert any(expect in r for r in regs), (expect, regs)


def test_budget_catches_new_signatures_and_findings():
    base = mk_report(events=[mk_event()], rounds=[mk_round()])
    budget = budget_for(base)
    extra_event = mk_report(events=[mk_event(), mk_event(key="L2")],
                            rounds=[mk_round()])
    regs, _ = check_reports([extra_event], budget)
    assert any("new event signature 'L2'" in r for r in regs)
    # a waived finding passes the rules, but if the budget has not pinned
    # it, the check still flags it as new
    waived = mk_report(events=[mk_event()], rounds=[mk_round()],
                       codec="int8", waivers={"R2": "ok"})
    regs, _ = check_reports([waived], budget)
    assert any("new finding" in r for r in regs)


def test_budget_unwaived_finding_always_fails():
    bad = mk_report(events=[mk_event(sync_ops=7)])
    regs, _ = check_reports([bad], budget_for(bad))
    assert any("unwaived finding R1" in r for r in regs)


def test_budget_improvements_pass_with_note():
    base = mk_report(events=[mk_event()])
    better = mk_report(events=[mk_event(sync_ops=1, expected=1, nbytes=248)])
    regs, imps = check_reports([better], budget_for(base))
    assert regs == []
    assert any("shrank" in i for i in imps)


def test_budget_update_merges_and_preserves_waivers():
    old = {"version": 1,
           "waivers": {"*int8*": {"R2": "baseline"}},
           "configs": {"mesh/only": {"events": {}, "rounds": {},
                                     "wire": None, "findings": []}}}
    rep = mk_report(events=[mk_event()], config="sim/new")
    new = update_budget(old, [rep])
    assert new["waivers"] == old["waivers"]
    assert "mesh/only" in new["configs"]  # not re-audited -> kept verbatim
    assert new["configs"]["sim/new"] == entry_from_report(rep)
    assert waivers_for(new, "sim/two_level/int8") == {"R2": "baseline"}
    assert waivers_for(new, "sim/two_level/off") == {}


def test_budget_missing_config_is_a_regression():
    rep = mk_report(events=[mk_event()], config="unknown/config")
    regs, _ = check_reports([rep], {"version": 1, "waivers": {},
                                    "configs": {}})
    assert any("not in budget" in r for r in regs)


# ---------------------------------------------------------------------------
# CLI gate against the committed budget
# ---------------------------------------------------------------------------
def test_cli_check_passes_against_committed_budget(tmp_path):
    """The CI step, in miniature: audit runnable configs, diff against the
    committed ANALYSIS_budget.json, write the report artifact."""
    out = tmp_path / "report.json"
    rc = main(["--check", "--configs", "sim/two_level/off,sim/two_level/int8",
               "--out", str(out)])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert "sim/two_level/off" in payload["configs"]
    # the compressed-collective burn-down: int8 is clean, nothing waived
    int8 = payload["configs"]["sim/two_level/int8"]
    assert int8["findings"] == []


def test_config_matrix_spans_the_lowering_paths():
    """Guard the matrix itself: both executors, comms off/identity/int8,
    and a multi-level schedule stay covered."""
    assert any(c.startswith("sim/") for c in CONFIGS)
    assert any(c.startswith("mesh/") for c in CONFIGS)
    assert any("three_level" in c for c in CONFIGS)
    assert any("int8" in c for c in CONFIGS)
    assert any("identity" in c for c in CONFIGS)
