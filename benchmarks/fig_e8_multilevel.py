"""Fig. E.8: 3-level H-SGD — mid-level aggregation helps, and the 3-level
sandwich (Remark 6) holds live."""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_world, mean_trajectories
from repro.core import HierarchySpec, local_sgd, make_topology

N_WORKERS = 8


def main(quick: bool = True):
    T = 96 if quick else 240
    ds, model = make_world(N_WORKERS)
    seeds = (0, 1, 2) if quick else tuple(range(6))

    def run(spec):
        return mean_trajectories(ds, model, lambda: make_topology(spec), T,
                                 seeds=seeds)[-1]

    res = {
        "P=2 (best case)": run(local_sgd(N_WORKERS, 2)),
        "3lvl P=(16,4,2)": run(HierarchySpec((2, 2, 2), (16, 4, 2))),
        "3lvl P=(16,8,2)": run(HierarchySpec((2, 2, 2), (16, 8, 2))),
        "2lvl G=16,I=2": run(HierarchySpec((2, 4), (16, 2))),
        "P=16 (worst case)": run(local_sgd(N_WORKERS, 16)),
    }
    print(f"# Fig E.8 — multi-level (T={T})")
    print("config,loss,acc")
    for k, v in res.items():
        print(f"{k},{v['loss']:.4f},{v['acc']:.4f}")
    eps = 0.02
    assert res["P=2 (best case)"]["loss"] <= \
        res["3lvl P=(16,4,2)"]["loss"] + eps
    assert res["3lvl P=(16,4,2)"]["loss"] <= \
        res["P=16 (worst case)"]["loss"] + eps
    # more mid-level aggregation (P2=4 vs 8) should not hurt
    assert res["3lvl P=(16,4,2)"]["loss"] <= \
        res["3lvl P=(16,8,2)"]["loss"] + eps
    return {k: v["loss"] for k, v in res.items()}


if __name__ == "__main__":
    main()
