"""Figs. E.4-E.6: partial worker participation — H-SGD retains its advantage
over local SGD when only a fraction of workers participate per round
(the paper's appendix experiments / stated future work, built into the
engine as a first-class mask)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_world
from repro.core import (HSGD, local_sgd, make_topology,
                        sample_participation, two_level)
from repro.optim import sgd

N_WORKERS = 16
FRAC = 0.5


def run(ds, model, spec, T, seed, frac=FRAC):
    topo = make_topology(spec)
    eng = HSGD(model.loss, sgd(0.08), topo, jit=True)
    st = eng.init(jax.random.PRNGKey(seed), model.init)
    sizes = (spec.group_sizes[0],
             spec.n_workers // spec.group_sizes[0])
    round_len = spec.periods[-1]
    mask = None
    for t in range(T):
        if t % round_len == 0:  # re-sample per aggregation round (paper E)
            mask = sample_participation(sizes, frac, seed * 10_000 + t)
        st, _ = eng.step(st, jax.tree.map(
            jnp.asarray, ds.batch(t, 10)), mask=mask)
    gb = jax.tree.map(jnp.asarray, ds.global_batch(640))
    wbar = eng.mean_params(st)
    return float(model.loss(wbar, gb)[0]), float(model.accuracy(wbar, gb))


def main(quick: bool = True):
    T = 96 if quick else 240
    ds, model = make_world(N_WORKERS, num_classes=8)
    seeds = (0, 1, 2) if quick else tuple(range(6))
    G, I = 16, 4

    res = {}
    for name, spec in [
        ("localSGD_P=4 (50% part.)", local_sgd(N_WORKERS, I)),
        ("hsgd G=16,I=4 (50% part.)", two_level(N_WORKERS, 2, G, I)),
        ("localSGD_P=16 (50% part.)", local_sgd(N_WORKERS, G)),
    ]:
        outs = [run(ds, model, spec, T, s) for s in seeds]
        res[name] = {"loss": float(np.mean([o[0] for o in outs])),
                     "acc": float(np.mean([o[1] for o in outs]))}
    print(f"# Fig E.4-E.6 — partial participation (frac={FRAC}, T={T}, "
          f"n={N_WORKERS})")
    print("config,loss,acc")
    for k, v in res.items():
        print(f"{k},{v['loss']:.4f},{v['acc']:.4f}")
    eps = 0.02
    ks = list(res)
    assert res[ks[0]]["loss"] <= res[ks[1]]["loss"] + eps   # sandwich holds
    assert res[ks[1]]["loss"] <= res[ks[2]]["loss"] + eps   # under sampling
    return {k: v["loss"] for k, v in res.items()}


if __name__ == "__main__":
    main()
