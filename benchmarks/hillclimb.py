"""§Perf hillclimbing: hypothesis -> change -> re-lower -> measure -> record,
for the three chosen (arch x shape) pairs.

Pairs (chosen from the baseline roofline table):
  * nemotron-4-340b x train_4k x multi — most representative of the paper's
    technique (H-SGD across pods at frontier scale); collective-dominant.
  * qwen2-0.5b      x train_4k x multi — worst useful-compute ratio (0.66):
    16-way tensor parallelism of a 0.5B model is the wrong layout.
  * mixtral-8x22b   x train_4k x multi — memory-dominant monster (MoE
    dispatch re-gathers expert weights every token group).

Each variant re-lowers the H-SGD train steps with one knob changed relative
to the current best, writes before/after terms to
benchmarks/results/perf.json, and marks confirmed/refuted.

Run:  PYTHONPATH=src python -m benchmarks.hillclimb [--pair nemotron...]
"""
import os  # noqa: E402  (device override must precede jax import)
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse   # noqa: E402
import dataclasses  # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from repro.configs import INPUT_SHAPES, get_config  # noqa: E402
from repro.launch.dryrun import HSGD_G, HSGD_I, lower_train  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.roofline import analyze_compiled, combine_train_steps  # noqa: E402

OUT = "benchmarks/results/perf.json"


def measure(arch: str, shape_name: str, *, cfg_over=None, **knobs):
    cfg = get_config(arch)
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    mesh = make_production_mesh(multi_pod=True)
    with jax.set_mesh(mesh):  # context mesh for act_pspec sharding constraints
        lowered = lower_train(cfg, INPUT_SHAPES[shape_name], mesh, **knobs)
        lowered.pop("_plan", None)
        reports = {}
        for kname, low in lowered.items():
            reports[kname] = analyze_compiled(kname, low.compile(), pod_size=256)
    amort = combine_train_steps(reports, HSGD_G, HSGD_I)
    head = reports.get("global_sync") or next(iter(reports.values()))
    return {
        "terms_s": {"compute": head.compute_s, "memory": head.memory_s,
                    "collective": head.collective_s},
        "amortized": amort,
        "peak_gb": (head.peak_memory_bytes or 0) / 1e9,
        "coll_cross_gb": head.coll_cross / 1e9,
        "coll_intra_gb": head.coll_intra / 1e9,
        "flops_per_chip": head.flops_per_chip,
    }


# ---------------------------------------------------------------------------
# iteration definitions: (name, hypothesis, cfg overrides, lower_train knobs)
# each entry's options are ABSOLUTE (already composed with the accepted
# predecessors, per the hillclimbing methodology)
# ---------------------------------------------------------------------------
ITERATIONS = {
    "nemotron-4-340b|train_4k": [
        ("baseline", "paper-faithful H-SGD, fsdp mapping, fp32 sync", {}, {}),
        ("act_shard",
         "the baseline HLO re-shards the residual stream every layer "
         "(per-layer activation all-gathers over 'data'); pinning acts to "
         "P(data, None, model) should remove them: collective term down "
         "several x, compute unchanged",
         {"act_pspec": ("data", None, "model")}, {}),
        ("remat",
         "memory term is residual-dominated (96 layers x 1.2GB saved "
         "carries); remat the unit body: bytes down ~2x for <= ~30% more "
         "flops (recompute)",
         {"act_pspec": ("data", None, "model"), "remat": True}, {}),
        ("bf16_sync",
         "cross-pod sync moves fp32 means (5.3GB/chip); bf16 payload halves "
         "the DCI bytes of the global sync at negligible convergence cost "
         "(beyond-paper; paper treats compression as orthogonal)",
         {"act_pspec": ("data", None, "model"), "remat": True},
         {"sync_dtype": "bfloat16"}),
        ("accum8",
         "peak 44.3GB still exceeds the 16GB HBM; accumulate gradients over "
         "8 microbatches (identical semantics for SGD, tested): peak "
         "activations / 8, terms ~unchanged",
         {"act_pspec": ("data", None, "model"), "remat": True},
         {"accum_steps": 8}),
    ],
    "qwen2-0.5b|train_4k": [
        ("baseline", "16-way TP of a 0.5B model: d=896 matmuls sliced to 56 "
         "columns; expect collective/memory-bound", {}, {}),
        ("dp_only",
         "replicate weights inside a worker (params fit trivially: 1GB) and "
         "shard the SEQUENCE over 'model' instead: TP all-reduces (0.3TB/"
         "chip/step) become tiny kv all-gathers; collective down ~10x",
         {}, {"model_shard": False, "seq_axis": "model"}),
        ("dp_only+bf16_sync",
         "with compute now local, the remaining collective is the param "
         "sync; halve it with bf16 payloads",
         {}, {"model_shard": False, "seq_axis": "model",
              "sync_dtype": "bfloat16"}),
        ("dp_only+chunk2048",
         "larger q-chunks (512->2048) cut scan trip count 4x: less loop "
         "overhead bytes, same flops",
         {"attn_chunk_q": 2048},
         {"model_shard": False, "seq_axis": "model",
          "sync_dtype": "bfloat16"}),
    ],
    "mixtral-8x22b|train_4k": [
        ("baseline", "fsdp mapping; MoE dispatch re-gathers expert weights "
         "every 2048-token group: memory-dominant", {}, {}),
        ("moe_group8k",
         "4x larger token groups -> 4x fewer expert-weight gathers per "
         "layer; dispatch tensor grows 16x but stays < 1GB: memory term "
         "down ~3-4x",
         {"moe_group": 8192}, {}),
        ("moe_group8k+remat",
         "then cut residual traffic with remat on the unit scan",
         {"moe_group": 8192, "remat": True}, {}),
        ("moe_group8k+remat+act_shard",
         "pin the residual stream to P(data, None, model) to stop per-layer "
         "re-sharding",
         {"moe_group": 8192, "remat": True,
          "act_pspec": ("data", None, "model")}, {}),
        ("group2k+remat+act_shard",
         "moe_group8k was (partially) refuted: dispatch-tensor flops/bytes "
         "scale with capacity, eating the fewer-weight-gathers win; revert "
         "to 2048-token groups while keeping remat + act_shard",
         {"remat": True, "act_pspec": ("data", None, "model")}, {}),
        ("gather_dispatch",
         "root cause isolated: the one-hot dispatch/combine einsums are "
         "O(T*E*C*d) — more flops+bytes than the experts themselves. "
         "Replace with an (E,C) token-id scatter + gathers (O(E*C*d) bytes, "
         "no dispatch matmul; numerically identical — tested): memory term "
         "down several x",
         {"moe_group": 8192, "remat": True, "moe_dispatch": "gather",
          "act_pspec": ("data", None, "model")}, {}),
        ("gather+group32k",
         "with gather dispatch the group size no longer costs dispatch "
         "flops; 4x bigger groups -> 4x fewer expert-weight re-reads per "
         "layer (the remaining memory term): memory down ~2-3x more",
         {"moe_group": 32768, "remat": True, "moe_dispatch": "gather",
          "act_pspec": ("data", None, "model")}, {}),
    ],
}


# ---------------------------------------------------------------------------
# bonus pair (beyond the required three): nemotron prefill — worst absolute
# baseline in the whole roofline table (collective 1003 s/step)
# ---------------------------------------------------------------------------
def measure_prefill(arch: str, shape_name: str, cfg_over=None):
    from repro.launch.dryrun import lower_prefill
    cfg = get_config(arch)
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    mesh = make_production_mesh(multi_pod=True)
    with jax.set_mesh(mesh):
        low = lower_prefill(cfg, INPUT_SHAPES[shape_name], mesh)["prefill"]
        rep = analyze_compiled("prefill", low.compile(), pod_size=256)
    return {
        "terms_s": {"compute": rep.compute_s, "memory": rep.memory_s,
                    "collective": rep.collective_s},
        "peak_gb": (rep.peak_memory_bytes or 0) / 1e9,
        "coll_intra_gb": rep.coll_intra / 1e9,
    }


SERVE_ITERATIONS = [
    ("baseline", "serving params FSDP'd over 'data' vs batch-sharded "
     "activations: GSPMD gathers 39GB f32 activations per layer", {}),
    ("act_shard",
     "pin the residual stream to P((pod,data), None, model): activations "
     "stay batch-sharded, weights get gathered instead (42GB once per "
     "layer, not per chunk): collective down ~5-10x",
     {"act_pspec": (("pod", "data"), None, "model")}),
    ("act_shard+chunk2048",
     "4x fewer q-chunk iterations -> 4x fewer per-chunk k/v re-gathers",
     {"act_pspec": (("pod", "data"), None, "model"), "attn_chunk_q": 2048}),
]


def run_serve_pair(results, force=False):
    pair = "nemotron-4-340b|prefill_32k"
    for name, hypothesis, cfg_over in SERVE_ITERATIONS:
        key = f"{pair}|{name}"
        if key in results and not force:
            print(f"skip (cached) {key}")
            continue
        print(f"=== {key}\n    hypothesis: {hypothesis}")
        t0 = time.time()
        try:
            rec = measure_prefill("nemotron-4-340b", "prefill_32k", cfg_over)
            rec["hypothesis"] = hypothesis
            rec["cfg_overrides"] = {k: str(v) for k, v in cfg_over.items()}
            rec["wall_s"] = round(time.time() - t0, 1)
            results[key] = rec
            t = rec["terms_s"]
            print(f"    terms: compute {t['compute']:.2f}s memory "
                  f"{t['memory']:.2f}s collective {t['collective']:.2f}s "
                  f"peak {rec['peak_gb']:.1f}GB")
        except Exception as e:
            traceback.print_exc()
            results[key] = {"error": str(e)[:500], "hypothesis": hypothesis}
        with open(OUT, "w") as f:
            json.dump(results, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default="all")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    results = {}
    if os.path.exists(OUT) and not args.force:
        with open(OUT) as f:
            results = json.load(f)

    if args.pair in ("all", "prefill"):
        run_serve_pair(results, force=args.force)
    for pair, iters in ITERATIONS.items():
        if args.pair != "all" and args.pair not in pair:
            continue
        arch, shape = pair.split("|")
        for name, hypothesis, cfg_over, knobs in iters:
            key = f"{pair}|{name}"
            if key in results and not args.force:
                print(f"skip (cached) {key}")
                continue
            print(f"=== {key}\n    hypothesis: {hypothesis}")
            t0 = time.time()
            try:
                rec = measure(arch, shape, cfg_over=cfg_over, **knobs)
                rec["hypothesis"] = hypothesis
                rec["cfg_overrides"] = {k: str(v) for k, v in cfg_over.items()}
                rec["knobs"] = {k: str(v) for k, v in knobs.items()}
                rec["wall_s"] = round(time.time() - t0, 1)
                results[key] = rec
                a = rec["amortized"]
                print(f"    amortized: compute {a['compute_s']:.3f}s "
                      f"memory {a['memory_s']:.3f}s "
                      f"collective {a['collective_s']:.3f}s "
                      f"(dominant {a['dominant']}) peak {rec['peak_gb']:.1f}GB")
            except Exception as e:
                traceback.print_exc()
                results[key] = {"error": str(e)[:500],
                                "hypothesis": hypothesis}
            with open(OUT, "w") as f:
                json.dump(results, f, indent=1)
    print("done")


if __name__ == "__main__":
    main()
