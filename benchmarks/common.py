"""Shared benchmark harness: live H-SGD training trajectories on the
paper's non-IID classification setup (CPU scale), plus the paper's
communication-time model (Table E.1)."""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, HSGD, HierarchySpec, make_topology
from repro.data import FederatedDataset, label_shard_partition, make_classification
from repro.models import SimpleConfig, SimpleModel
from repro.optim import sgd

# Table E.1 (ms per aggregation round) + measured 4 ms/iteration compute
COMM_MS = {
    "cnn": {"near": 0.29, "far": 4.53},
    "vgg11": {"near": 27.81, "far": 291.82},
}
COMPUTE_MS_PER_ITER = 4.0


def make_world(n_workers: int = 8, num_classes: int = 8, dim: int = 24,
               seed: int = 3):
    x, y = make_classification(seed, num_classes=num_classes, dim=dim,
                               per_class=80, spread=1.5)
    parts = label_shard_partition(
        y, [[j % num_classes] for j in range(n_workers)],
        n_workers=n_workers)
    ds = FederatedDataset(x, y, parts).require_workers(n_workers)
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=dim, hidden=32,
                                     num_classes=num_classes))
    return ds, model


def trajectory(ds, model, topology, T: int, lr: float = 0.08, seed: int = 0,
               bs: int = 10, eval_every: int = 8,
               use_rounds: bool = False, backend: str = "sim",
               comms=None, metrics=None) -> List[Dict]:
    """use_rounds=True runs the schedule-compiled ``run_rounds`` executor
    (same trajectory — tested — fewer dispatches); eval points then land on
    the round boundaries hit by ``eval_every``.  ``backend`` picks the
    executor ("sim" | "mesh"); mesh needs one device per worker.  ``comms``
    selects a communication plan (codec name / repro.comms.Comms);
    ``metrics`` the in-graph probe plan ("on" / repro.obs.Metrics)."""
    if isinstance(topology, HierarchySpec):
        topology = make_topology(topology)
    eng = HSGD(model.loss, sgd(lr), topology,
               EngineConfig(jit=True, executor=backend, comms=comms,
                            metrics=metrics))
    st = eng.init(jax.random.PRNGKey(seed), model.init)
    gb = jax.tree.map(jnp.asarray, ds.global_batch(640))

    def evaluate(state):
        wbar = eng.mean_params(state)
        return {"loss": float(model.loss(wbar, gb)[0]),
                "acc": float(model.accuracy(wbar, gb))}

    batch_fn = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, bs))
    if use_rounds:
        st, hist = eng.run_rounds(
            st, batch_fn, T, eval_every=eval_every,
            eval_fn=lambda state, t: evaluate(state))
        return [{"step": rec["t"], "loss": rec["loss"], "acc": rec["acc"]}
                for rec in hist if "acc" in rec]
    hist = []
    for t in range(T):
        st, _ = eng.step(st, batch_fn(t))
        if (t + 1) % eval_every == 0 or t + 1 == T:
            hist.append({"step": t + 1, **evaluate(st)})
    return hist


def steps_per_sec(ds, model, topology, T: int = 256, lr: float = 0.08,
                  bs: int = 10, use_rounds: bool = False,
                  warmup: int = 32, backend: str = "sim",
                  comms=None, metrics=None) -> float:
    """Wall-clock throughput of the trajectory harness (no evals): the
    per-step dispatcher vs the schedule-compiled round executor, on either
    execution backend ("sim" | "mesh"), with an optional comms plan and
    metrics probe plan (``metrics="on"`` for the R6 overhead contract)."""
    if isinstance(topology, HierarchySpec):
        topology = make_topology(topology)
    eng = HSGD(model.loss, sgd(lr), topology,
               EngineConfig(jit=True, executor=backend, comms=comms,
                            metrics=metrics))
    st = eng.init(jax.random.PRNGKey(0), model.init)
    # warmup must span >= one full global period so EVERY step/round
    # signature compiles before the timed region, and end on a period
    # boundary so the timed region is phase-aligned with the cached rounds
    G = topology.periods[0]
    warmup = -(-max(warmup, G) // G) * G
    batches = [jax.tree.map(jnp.asarray, ds.batch(t, bs))
               for t in range(T + warmup)]
    batch_fn = lambda t: batches[t]

    def go(state, t0, steps):
        if use_rounds:
            state, _ = eng.run_rounds(state, batch_fn, steps)
        else:
            for t in range(t0, t0 + steps):
                state, _ = eng.step(state, batch_fn(t))
        return state

    st = go(st, 0, warmup)  # compile + cache every round/step signature
    jax.block_until_ready(st.params)
    t0 = time.time()
    st = go(st, warmup, T)
    jax.block_until_ready(st.params)
    return T / (time.time() - t0)


def mean_trajectories(ds, model, topo_fn, T, seeds=(0, 1, 2), **kw):
    runs = [trajectory(ds, model, topo_fn(), T, seed=s, **kw) for s in seeds]
    out = []
    for recs in zip(*runs):
        out.append({"step": recs[0]["step"],
                    "loss": float(np.mean([r["loss"] for r in recs])),
                    "acc": float(np.mean([r["acc"] for r in recs]))})
    return out


def comm_time_ms(spec: HierarchySpec, steps: int, model_kind: str = "cnn",
                 single_level_is_far: bool = True) -> float:
    """Paper communication model: every level-M (innermost) aggregation costs
    a near round; every level-1 (global) aggregation a far round; single-level
    local SGD always pays the far cost (workers -> global server)."""
    c = COMM_MS[model_kind]
    counts = spec.sync_counts(steps)
    total = steps * COMPUTE_MS_PER_ITER
    if spec.num_levels == 1:
        return total + counts[0] * (c["far"] if single_level_is_far
                                    else c["near"])
    return total + counts[0] * c["far"] + sum(counts[1:]) * c["near"]


def time_to_target(hist: List[Dict], spec: HierarchySpec, target_acc: float,
                   model_kind: str = "cnn") -> Optional[float]:
    for rec in hist:
        if rec["acc"] >= target_acc:
            return comm_time_ms(spec, rec["step"], model_kind)
    return None
