"""Runtime benchmark: simulated time-to-accuracy per participation policy x
straggler regime x topology.

The paper's thesis is convergence per WALL-CLOCK cost; this benchmark prices
the clock side with the :mod:`repro.runtime` simulated-time engine and
demonstrates the payoff of deadline-elastic participation: under stragglers,
dropping late workers from individual sync barriers reaches the same target
accuracy in less *simulated* time than the full-barrier baseline — while a
homogeneous fleet is left bitwise untouched.

Everything here is SIMULATED time (host-side numpy accounting) — there is no
wall-clock measurement in this benchmark at all, per the repo's
jaxpr-not-wall-clock verification rule, so the numbers are deterministic and
CI-assertable:

* monotonicity: ``sim_time_s`` never decreases along a trajectory;
* elastic-never-slower: per step, elastic ``sim_time_s`` <= full-barrier
  ``sim_time_s`` under EVERY straggler regime (same seed = identical
  compute draws; see repro/runtime/clock.py for the induction);
* no-straggler transparency: with a homogeneous fleet nobody misses a
  deadline, so elastic == full barrier in both trajectory and time;
* the payoff: with a straggler regime enabled, elastic PUBLISHES a
  target-accuracy global model in strictly less simulated time — timed at
  the global barrier's completion (``SimClock.last_sync_time``), i.e. when
  the server actually holds the aggregate, not at the fleet makespan a
  deliberately-dropped straggler would gate.

Emits ``BENCH_runtime.json`` (schema: {topology: {regime: {policy: rec}}});
the CI smoke step runs ``--smoke`` in the device matrix and uploads it next
to BENCH_comms.json.  ``--backend mesh|both`` adds a mesh leg per regime:
the elastic arm re-runs through the shard_map backend in exact mode, the
trajectory AND the simulated clocks are asserted bitwise-identical to the
sim arm (the host-side clock is executor-independent by construction), and
the record lands as ``elastic_mesh`` next to the sim arms — so the file
documents that the elastic x straggler matrix RUNS on the backend that
scales, per the same no-wall-clock rule.

    PYTHONPATH=src python benchmarks/bench_runtime.py [--smoke] [--out PATH]
        [--backend sim|mesh|both]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import make_world  # noqa: E402
from repro.core import HSGD, HierarchySpec, make_topology
from repro.optim import sgd
from repro.runtime import LinkModel, RuntimeModel

# near-vs-far link ladders (outermost = level 1 = the slow fabric); payloads
# here are tiny, so latency dominates and the numbers are stable
TOPOLOGIES = {
    "two_level": (HierarchySpec((2, 4), (8, 2)),
                  (LinkModel(2.0, 1e8), LinkModel(0.1, 1e9))),
    "three_level": (HierarchySpec((2, 2, 2), (8, 4, 2)),
                    (LinkModel(2.0, 1e8), LinkModel(0.2, 1e9),
                     LinkModel(0.05, 1e10))),
}

REGIMES = {
    "none": None,
    "fixed": "fixed:0.125:8",          # one worker permanently 8x slower
    "lognormal": "lognormal:0.8",      # heavy-tailed per-step jitter
    "bursty": "bursty:0.08:0.3:8",     # transient 8x contention bursts
}

COMPUTE_S = 1.0
LR = 0.05
TARGET_FRAC = 0.99  # of the weaker arm's best accuracy
DEADLINE_S = 2.0    # slack over the subtree's median arrival, every level
SEED = 1


def make_mesh_executor(spec):
    """The mesh arm runs exact=True: the replayed sim reduce is bitwise, so
    the cross-backend assertion is deterministic (no tolerance tuning) and
    the recorded numbers are PROOF of parity, not a second estimate."""
    from repro.core import MeshExecutor
    from repro.launch.mesh import make_host_mesh
    return MeshExecutor(make_host_mesh(group_sizes=spec.group_sizes),
                        exact=True)


def run_arm(ds, model, spec, links, straggler, deadline, T, eval_every=8,
            executor="sim"):
    topo = make_topology("uniform", spec=spec)
    rt = RuntimeModel(compute_s=COMPUTE_S, links=links, straggler=straggler,
                      policy=deadline, seed=SEED)
    eng = HSGD(model.loss, sgd(LR), topo, runtime=rt, executor=executor)
    st = eng.init(jax.random.PRNGKey(0), model.init)
    gb = jax.tree.map(jnp.asarray, ds.global_batch(640))

    def evaluate(state, t):
        # the PUBLISHED global model: eval cadence == G, so every eval point
        # sits right after a global sync, where the sync's admitted workers
        # all hold the aggregate — available at the barrier-completion time
        # last_sync_time[1], regardless of where any dropped straggler's own
        # clock is.  (Full barrier admits everyone, so there this is the
        # plain w-bar at the fleet makespan.)
        clock = eng._last_clock
        adm = clock.last_admitted.get(1)
        adm = np.ones(topo.n, bool) if adm is None else adm
        wbar = jax.tree.map(
            lambda x: x[adm].mean(0, dtype=jnp.float32).astype(x.dtype),
            state.params)
        return {"acc": float(model.accuracy(wbar, gb)),
                "pub_time_s": round(clock.last_sync_time.get(1,
                                                             clock.time_s), 6)}

    batch_fn = lambda t: jax.tree.map(jnp.asarray, ds.batch(t, 10))
    st, hist = eng.run_rounds(st, batch_fn, T, eval_every=eval_every,
                              eval_fn=evaluate)
    return eng, hist


def time_to_target(hist, target_acc):
    """First eval point at target: (step, published-model time, makespan)."""
    for rec in hist:
        if rec.get("acc", -1.0) >= target_acc:
            return rec["t"], rec["pub_time_s"], rec["sim_time_s"]
    return None, None, None


def bench_regime(ds, model, spec, links, straggler, T, mesh: bool = False):
    eng_f, hist_f = run_arm(ds, model, spec, links, straggler, None, T)
    eng_e, hist_e = run_arm(ds, model, spec, links, straggler, DEADLINE_S, T)

    tf = [r["sim_time_s"] for r in hist_f]
    te = [r["sim_time_s"] for r in hist_e]
    # invariant 1: monotone clocks
    assert all(a <= b for a, b in zip(tf, tf[1:])), "full-barrier time ran backwards"
    assert all(a <= b for a, b in zip(te, te[1:])), "elastic time ran backwards"
    # invariant 2: elastic is never slower, pointwise per step
    assert all(e <= f + 1e-9 for e, f in zip(te, tf)), \
        "elastic exceeded full-barrier simulated time"

    accs = lambda h: [r["acc"] for r in h if "acc" in r]
    target = TARGET_FRAC * min(max(accs(hist_f)), max(accs(hist_e)))
    sf, ttf, mf = time_to_target(hist_f, target)
    se, tte, me = time_to_target(hist_e, target)
    assert ttf is not None and tte is not None, "an arm never reached target"

    def rec(eng, hist, steps, t_pub, t_make):
        rep = eng.runtime_report()
        return {"steps_to_target": steps,
                "time_to_target_s": t_pub,          # published-model time
                "makespan_at_target_s": t_make,     # incl. dropped clocks
                "total_sim_time_s": hist[-1]["sim_time_s"],
                "final_sync_s": hist[-1]["sim_sync_s"],
                "best_acc": round(max(accs(hist)), 4),
                "dropped": rep["dropped"], "synced": rep["synced"]}

    out = {
        "target_acc": round(target, 4),
        "full_barrier": rec(eng_f, hist_f, sf, ttf, mf),
        "elastic": rec(eng_e, hist_e, se, tte, me),
        "speedup_at_target": round(ttf / tte, 4),
    }
    if mesh:
        # the mesh leg: the same elastic x straggler matrix through the
        # shard_map backend.  exact=True replays the sim reduce, so the
        # whole history — losses, accs, masks, simulated clocks — must be
        # IDENTICAL to the sim arm (asserted); the record proves the mesh
        # backend runs the elastic regime, it does not re-estimate it.
        eng_me, hist_me = run_arm(ds, model, spec, links, straggler,
                                  DEADLINE_S, T,
                                  executor=make_mesh_executor(spec))
        assert [r["sim_time_s"] for r in hist_me] == \
            [r["sim_time_s"] for r in hist_e], "mesh clock diverged from sim"
        # params replay bitwise, so the published-model accuracies (computed
        # FROM params at every eval point) must be exactly equal; the ce
        # METRIC reduces in a different order (per-shard mean + pmean vs one
        # in-array mean), so it only matches to f32 rounding
        assert [r.get("acc") for r in hist_me] == \
            [r.get("acc") for r in hist_e], \
            "mesh(exact) trajectory diverged from sim"
        assert all(abs(a["ce"] - b["ce"]) < 1e-5
                   for a, b in zip(hist_me, hist_e))
        sm, ttm, mm = time_to_target(hist_me, target)
        out["elastic_mesh"] = dict(rec(eng_me, hist_me, sm, ttm, mm),
                                   backend="mesh(exact)",
                                   params_bitwise_vs_sim=True)
    return out, (hist_f, hist_e)


def main(quick: bool = True, out: str = "BENCH_runtime.json",
         backend: str = "sim") -> dict:
    # num_classes=4 over 8 workers = every class on TWO workers: dropping a
    # straggler from a sync never orphans its data — the redundant-coverage
    # regime elastic participation is designed for (with one worker per
    # class, permanently dropping a fixed straggler caps the reachable
    # accuracy instead; that bias is real, not a bug — see test_runtime.py)
    mesh = backend in ("mesh", "both")
    if mesh and len(jax.devices()) < 8:
        raise SystemExit(
            "--backend mesh needs 8 devices: export "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax "
            "initializes (the CI 8-device leg does)")
    ds, model = make_world(n_workers=8, num_classes=4)
    T = 96 if quick else 384
    report = {"steps": T, "compute_s": COMPUTE_S, "deadline_s": DEADLINE_S,
              "backend": backend, "topologies": {}}
    for tname, (spec, links) in TOPOLOGIES.items():
        row = {"spec": {"group_sizes": spec.group_sizes,
                        "periods": spec.periods},
               "links": [{"latency_s": l.latency_s,
                          "bandwidth_Bps": l.bandwidth_Bps} for l in links]}
        for rname, straggler in REGIMES.items():
            print(f"... {tname} / {rname}")
            row[rname], (hist_f, hist_e) = bench_regime(
                ds, model, spec, links, straggler, T, mesh=mesh)
            if rname == "none":
                # homogeneous fleet: nobody misses a deadline, so elastic is
                # the SAME run — identical losses and identical clocks
                assert [r["ce"] for r in hist_f] == [r["ce"] for r in hist_e]
                assert [r["sim_time_s"] for r in hist_f] == \
                    [r["sim_time_s"] for r in hist_e]
            else:
                # the headline: under stragglers, deadline-elastic H-SGD
                # publishes a target-accuracy global model in LESS simulated
                # time than the full-barrier baseline
                assert row[rname]["elastic"]["time_to_target_s"] < \
                    row[rname]["full_barrier"]["time_to_target_s"], \
                    (tname, rname, row[rname])
        report["topologies"][tname] = row
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out}")
    summary = {t: {r: row[r]["speedup_at_target"] for r in REGIMES}
               for t, row in report["topologies"].items()}
    print(json.dumps(summary))
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: shorter horizon (the accounting is "
                         "simulated either way — nothing here measures "
                         "wall-clock)")
    ap.add_argument("--full", action="store_true", help="longer runs")
    ap.add_argument("--backend", default="sim",
                    choices=["sim", "mesh", "both"],
                    help="'mesh'/'both' additionally runs the elastic arm "
                         "of every regime through the shard_map backend "
                         "(exact mode) and asserts the trajectory and the "
                         "simulated clocks are bitwise the sim arm's — "
                         "recorded per regime as 'elastic_mesh' (needs 8 "
                         "devices)")
    ap.add_argument("--out", default="BENCH_runtime.json")
    args = ap.parse_args()
    main(quick=args.smoke or not args.full, out=args.out,
         backend=args.backend)
