"""Benchmark entrypoint — one function per paper table/figure.

Prints a ``name,us_per_call,derived`` CSV summary line per benchmark (the
per-benchmark detail CSVs print above each summary).  Run:

    PYTHONPATH=src python -m benchmarks.run [--full]
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks import (fig3_sandwich, fig3c_grouping, fig_e4_participation,
                        fig_e8_multilevel, roofline_table, table1_bounds,
                        table2_time_to_acc)

BENCHES = [
    ("table1_bounds", table1_bounds.main),
    ("fig3_sandwich", fig3_sandwich.main),
    ("fig3c_grouping", fig3c_grouping.main),
    ("table2_time_to_acc", table2_time_to_acc.main),
    ("fig_e8_multilevel", fig_e8_multilevel.main),
    ("fig_e4_participation", fig_e4_participation.main),
    ("roofline_table", roofline_table.main),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer runs / more seeds")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    summary = []
    for name, fn in BENCHES:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====")
        t0 = time.time()
        derived = fn(quick=not args.full)
        us = (time.time() - t0) * 1e6
        summary.append((name, us, derived))

    print("\n# summary")
    print("name,us_per_call,derived")
    for name, us, derived in summary:
        d = json.dumps(derived, default=str)[:160].replace(",", ";")
        print(f"{name},{us:.0f},{d}")


if __name__ == "__main__":
    main()
