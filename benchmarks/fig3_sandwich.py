"""Fig. 3a/3b: the sandwich behaviour and the G-up/I-down trade, live.

 3a: H-SGD(G, I) final loss sits between local SGD P=I and P=G; larger N
     degrades H-SGD (upward divergence grows, Remark 4).
 3b: increasing G while decreasing I (G=64,I=2 vs G=16,I=4) matches or beats
     the smaller-G config with 4x fewer global aggregations (Remark 5).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_world, mean_trajectories
from repro.core import make_topology

N_WORKERS = 8


def main(quick: bool = True):
    T = 96 if quick else 240
    G, I = 16, 4
    ds, model = make_world(N_WORKERS)
    seeds = (0, 1, 2) if quick else tuple(range(6))

    def run(topo_fn):
        return mean_trajectories(ds, model, topo_fn, T, seeds=seeds)[-1]

    res = {
        "localSGD_P=I": run(lambda: make_topology("local_sgd", n=N_WORKERS, P=I)),
        "hsgd_N2": run(lambda: make_topology("two_level", n=N_WORKERS, N=2, G=G, I=I)),
        "hsgd_N4": run(lambda: make_topology("two_level", n=N_WORKERS, N=4, G=G, I=I)),
        "localSGD_P=G": run(lambda: make_topology("local_sgd", n=N_WORKERS, P=G)),
        "hsgd_G64_I2": run(lambda: make_topology("two_level", n=N_WORKERS, N=2, G=64, I=2)),
    }
    print("# Fig 3a/3b — sandwich + G-up/I-down (mean final loss/acc, "
          f"T={T}, n={N_WORKERS})")
    print("config,loss,acc")
    for k, v in res.items():
        print(f"{k},{v['loss']:.4f},{v['acc']:.4f}")

    eps = 0.02
    assert res["localSGD_P=I"]["loss"] <= res["hsgd_N2"]["loss"] + eps
    assert res["hsgd_N2"]["loss"] <= res["localSGD_P=G"]["loss"] + eps
    # Remark 4: larger N => larger upward divergence => no better
    assert res["hsgd_N2"]["loss"] <= res["hsgd_N4"]["loss"] + eps
    # Fig 3b spirit: raising G 16->64 (4x fewer global aggregations) while
    # lowering I 4->2 still clearly beats local SGD with P=16.  (Remark 5's
    # exact feasibility l<sqrt((n-N)/(N m^2)+1)~1.09 does not cover l=4 at
    # n=8 — the paper's own Fig 3b also operates outside it empirically.)
    assert res["hsgd_G64_I2"]["loss"] <= res["localSGD_P=G"]["loss"] + eps
    return {k: v["loss"] for k, v in res.items()}


if __name__ == "__main__":
    main()
