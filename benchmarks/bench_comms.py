"""Communication benchmark: bytes/step and steps/sec per codec x topology.

The paper's thesis is convergence per COMMUNICATION COST; this benchmark
makes the cost side concrete.  For each codec (off / identity / int8 /
sign / topk) on a 2-level and a 3-level hierarchy it reports

* the static wire accounting (``repro.comms.WireStats``): per-worker payload
  bytes, per-level bytes per sync, bytes/step over the schedule, and the
  payload reduction vs the f32 baseline (int8 ~4x, sign ~30x);
* measured steps/sec of the live training harness (sim executor), so codec
  compute overhead is visible next to the byte savings.

Emits ``BENCH_comms.json`` (schema: {topology: {codec: record}}) — the CI
smoke step runs ``--smoke`` and uploads it as an artifact, so the numbers
regenerate on every push and bit-rot fails CI.  The byte ratios are
asserted (they are static — no timing noise); throughput is reported only.

    PYTHONPATH=src python benchmarks/bench_comms.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax

# runnable both as `python -m benchmarks.bench_comms` and as a plain script
# (`python benchmarks/bench_comms.py`, the CI smoke invocation)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import make_world, steps_per_sec  # noqa: E402
from repro.comms import Comms
from repro.core import HSGD, HierarchySpec, make_topology
from repro.optim import sgd

TOPOLOGIES = {
    "two_level": HierarchySpec((2, 4), (8, 2)),
    "three_level": HierarchySpec((2, 2, 2), (8, 4, 2)),
}

CODECS = {
    "off": None,                       # comms disabled: the baseline path
    "identity": Comms("identity"),     # FlatBucket fusion, exact values
    "int8": Comms("int8"),
    "sign": Comms("sign"),
    "topk": Comms("topk"),
}


def bench_one(ds, model, spec: HierarchySpec, comms, T: int,
              measure: bool) -> dict:
    topo = make_topology("uniform", spec=spec)
    eng = HSGD(model.loss, sgd(0.08), topo, comms=comms)
    state = eng.init(jax.random.PRNGKey(0), model.init)
    rec = {}
    ws = eng.wire_stats(state)
    if ws is not None:
        rec.update(ws.summary(T))
    # static audit of the LOWERED sync programs (repro.analysis): the
    # O(dtypes)-vs-O(leaves) claim per sync level, asserted at generation
    # time against the schedule prediction — a jaxpr walk, not wall-clock
    audit = eng.audit(state)
    rec["sync_ops"] = {k: ev.sync_ops for k, ev in audit.events.items()}
    for ev in audit.events.values():
        assert ev.sync_ops == ev.expected_sync_ops, \
            f"lowered sync op count drifted: {ev}"
    if measure:
        rec["steps_per_sec"] = round(
            steps_per_sec(ds, model, make_topology("uniform", spec=spec),
                          T=T, use_rounds=True, warmup=spec.G, comms=comms),
            2)
    return rec


def main(quick: bool = True, out: str = "BENCH_comms.json",
         measure: bool = True) -> dict:
    ds, model = make_world(n_workers=8)
    T = 64 if quick else 512
    report = {"steps": T, "topologies": {}}
    for tname, spec in TOPOLOGIES.items():
        row = {"spec": {"group_sizes": spec.group_sizes,
                        "periods": spec.periods}}
        for cname, comms in CODECS.items():
            print(f"... {tname} / {cname}")
            row[cname] = bench_one(ds, model, spec, comms, T, measure)
        # static sanity: the whole point of the codecs (driver-asserted)
        ident = row["identity"]["payload_bytes_per_worker"]
        assert row["int8"]["compression_ratio"] > 3.5, row["int8"]
        assert row["sign"]["compression_ratio"] > 20.0, row["sign"]
        assert row["identity"]["compression_ratio"] == 1.0
        assert row["int8"]["payload_bytes_per_worker"] < ident
        report["topologies"][tname] = row
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out}")
    summary = {t: {c: row[c].get("compression_ratio")
                   for c in CODECS if c != "off"}
               for t, row in report["topologies"].items()}
    print(json.dumps(summary))
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: short run, skip throughput timing")
    ap.add_argument("--full", action="store_true", help="longer runs")
    ap.add_argument("--out", default="BENCH_comms.json")
    args = ap.parse_args()
    main(quick=not args.full, out=args.out, measure=not args.smoke)
