"""Communication benchmark: bytes/step and steps/sec per codec x topology.

The paper's thesis is convergence per COMMUNICATION COST; this benchmark
makes the cost side concrete.  For each codec (off / identity / int8 /
sign / topk) on a 2-level and a 3-level hierarchy it reports

* the static wire accounting (``repro.comms.WireStats``): per-worker payload
  bytes, per-level bytes per sync, bytes/step over the schedule, and the
  payload reduction vs the f32 baseline (int8 ~4x, sign ~30x);
* measured steps/sec of the live training harness (sim executor), so codec
  compute overhead is visible next to the byte savings.

Emits ``BENCH_comms.json`` (schema: {topology: {codec: record}}) — the CI
smoke step runs ``--smoke`` and uploads it as an artifact, so the numbers
regenerate on every push and bit-rot fails CI.  The byte ratios are
asserted (they are static — no timing noise); per-codec throughput inside
the codec records is reported only.

``--wall-clock`` adds a timed leg on the two-level hierarchy, recorded
under ``wall_clock`` in the JSON: interleaved steps/sec per codec x
backend including the legacy ``wire_reduce=False`` (encode -> reduce
decoded f32 -> decode) lowering of the compressing codecs, plus an
isolated many-iteration timing of each codec's jitted sync.  Two bounds
are asserted at generation time, each on the measurement where its margin
beats this box's ~20% throughput jitter: the identity codec lands within
5% of comms-off on the best same-rep steps/sec pairing (back-to-back runs
share machine state), and the int8/sign compressed collectives beat their
own legacy roundtrip lowering on mean sync latency (averaged over
thousands of calls, so scheduler noise integrates out).

    PYTHONPATH=src python benchmarks/bench_comms.py \
        [--smoke] [--full] [--wall-clock] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax

# runnable both as `python -m benchmarks.bench_comms` and as a plain script
# (`python benchmarks/bench_comms.py`, the CI smoke invocation)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import make_world, steps_per_sec  # noqa: E402
from repro.comms import Comms
from repro.core import HSGD, HierarchySpec, make_topology
from repro.optim import sgd

TOPOLOGIES = {
    "two_level": HierarchySpec((2, 4), (8, 2)),
    "three_level": HierarchySpec((2, 2, 2), (8, 4, 2)),
}

CODECS = {
    "off": None,                       # comms disabled: the baseline path
    "identity": Comms("identity"),     # FlatBucket fusion, exact values
    "int8": Comms("int8"),
    "sign": Comms("sign"),
    "topk": Comms("topk"),
}

# the pre-compressed-collective lowering of the same codecs: encode, reduce
# the DECODED f32 payload, decode — what the wire path has to beat
LEGACY = {
    "int8-legacy": Comms("int8", wire_reduce=False),
    "sign-legacy": Comms("sign", wire_reduce=False),
}

# wall-clock repeats: this box's slow phases last seconds and swing
# throughput by ~20%, so every repeat times ALL variants back-to-back and
# each variant keeps its best — the bests sample the same fast machine
# state, which is what makes ratios between them comparable
WALL_REPEATS = 3


def wall_clock_leg(ds, model, spec: HierarchySpec, T: int,
                   backends) -> dict:
    """Interleaved best-of-``WALL_REPEATS`` steps/sec per codec (plus the
    legacy roundtrip variants) for each backend."""
    variants = dict(CODECS)
    variants.update(LEGACY)
    out = {}
    for backend in backends:
        runs = {name: [] for name in variants}
        for rep in range(WALL_REPEATS):
            for name, comms in variants.items():
                topo = make_topology("uniform", spec=spec)
                runs[name].append(steps_per_sec(
                    ds, model, topo, T=T, backend=backend, comms=comms))
            print(f"... wall-clock {backend} rep {rep}: " + " ".join(
                f"{n}={runs[n][-1]:.0f}" for n in runs))
        out[backend] = {name: {"steps_per_sec_best": round(max(v), 2),
                               "steps_per_sec_all": [round(x, 2) for x in v]}
                        for name, v in runs.items()}
    return out


def sync_latency_leg(model, spec: HierarchySpec, iters: int = 1500) -> dict:
    """Wall-clock of each codec's jitted L1 sync (sim arithmetic, the same
    graph both executors' wire path lowers from), in microseconds: the min
    over ``WALL_REPEATS`` interleaved passes of an ``iters``-call mean.
    The long mean integrates out scheduler noise and the min discards
    whole passes that landed in a slow machine phase, so ~10-20%
    wire-vs-legacy margins are resolvable even on a box whose end-to-end
    steps/sec jitters more than that."""
    import time

    from repro.comms.reduce import SimWireOps
    from repro.core.topology import SyncEvent

    topo = make_topology("uniform", spec=spec)
    params = model.init(jax.random.PRNGKey(0))
    n = spec.n_workers
    tree = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(1),
                                    (n,) + x.shape), params)
    ev = SyncEvent(level=1)
    ops = SimWireOps(spec.group_sizes, 1)

    def reduce_fn(t):
        return topo.aggregate(t, ev)

    variants = dict(CODECS)
    variants.update(LEGACY)
    fns = {}
    for name, comms in variants.items():
        if comms is None:
            fns[name] = jax.jit(reduce_fn)
        elif comms.wire_reduce and comms.codec.wire_reduce:
            fns[name] = jax.jit(
                lambda t, c=comms: c.sync(t, reduce_fn, reduce_mode=ops)[0])
        else:
            fns[name] = jax.jit(lambda t, c=comms: c.sync(t, reduce_fn)[0])
    out = {name: float("inf") for name in fns}
    for _ in range(WALL_REPEATS):
        for name, fn in fns.items():
            r = fn(tree)
            jax.block_until_ready(r)
            t0 = time.perf_counter()
            for _ in range(iters):
                r = fn(tree)
            jax.block_until_ready(r)
            us = (time.perf_counter() - t0) / iters * 1e6
            out[name] = round(min(out[name], us), 1)
    print("... sync latency (us, min of interleaved means): " + " ".join(
        f"{n}={v}" for n, v in out.items()))
    return out


def bench_one(ds, model, spec: HierarchySpec, comms, T: int,
              measure: bool) -> dict:
    topo = make_topology("uniform", spec=spec)
    eng = HSGD(model.loss, sgd(0.08), topo, comms=comms)
    state = eng.init(jax.random.PRNGKey(0), model.init)
    rec = {}
    ws = eng.wire_stats(state)
    if ws is not None:
        rec.update(ws.summary(T))
    # static audit of the LOWERED sync programs (repro.analysis): the
    # O(dtypes)-vs-O(leaves) claim per sync level, asserted at generation
    # time against the schedule prediction — a jaxpr walk, not wall-clock
    audit = eng.audit(state)
    rec["sync_ops"] = {k: ev.sync_ops for k, ev in audit.events.items()}
    for ev in audit.events.values():
        assert ev.sync_ops == ev.expected_sync_ops, \
            f"lowered sync op count drifted: {ev}"
    if measure:
        rec["steps_per_sec"] = round(
            steps_per_sec(ds, model, make_topology("uniform", spec=spec),
                          T=T, use_rounds=True, warmup=spec.G, comms=comms),
            2)
    return rec


def main(quick: bool = True, out: str = "BENCH_comms.json",
         measure: bool = True, wall_clock: bool = False) -> dict:
    ds, model = make_world(n_workers=8)
    T = 64 if quick else 512
    report = {"steps": T, "topologies": {}}
    for tname, spec in TOPOLOGIES.items():
        row = {"spec": {"group_sizes": spec.group_sizes,
                        "periods": spec.periods}}
        for cname, comms in CODECS.items():
            print(f"... {tname} / {cname}")
            row[cname] = bench_one(ds, model, spec, comms, T, measure)
        # static sanity: the whole point of the codecs (driver-asserted)
        ident = row["identity"]["payload_bytes_per_worker"]
        assert row["int8"]["compression_ratio"] > 3.5, row["int8"]
        assert row["sign"]["compression_ratio"] > 20.0, row["sign"]
        assert row["identity"]["compression_ratio"] == 1.0
        assert row["int8"]["payload_bytes_per_worker"] < ident
        report["topologies"][tname] = row
    if wall_clock:
        spec = TOPOLOGIES["two_level"]
        backends = ["sim"] + (["mesh"] if len(jax.devices()) >= spec.G
                              else [])
        wc = wall_clock_leg(ds, model, spec, 256 if quick else 1024,
                            backends)
        lat = sync_latency_leg(model, spec)
        report["wall_clock"] = {"repeats": WALL_REPEATS,
                                "steps": 256 if quick else 1024,
                                "two_level": wc,
                                "sync_latency_us": lat}
        sim = wc["sim"]

        # the wall-clock contract of the compressed-collective lowering.
        # (1) identity pays nothing over comms-off: bucket elision makes
        # its sync graph the off path's per-leaf mean, so the best
        # SAME-REP pairing (adjacent runs share machine state) must sit
        # within 5%
        pairs = [i / o for i, o in zip(sim["identity"]["steps_per_sec_all"],
                                       sim["off"]["steps_per_sec_all"])]
        assert max(pairs) >= 0.95, (pairs, sim)
        # (2) the wire paths beat their own legacy
        # encode->reduce(f32)->decode form on mean sync latency
        assert lat["int8"] < lat["int8-legacy"], lat
        assert lat["sign"] < lat["sign-legacy"], lat
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out}")
    summary = {t: {c: row[c].get("compression_ratio")
                   for c in CODECS if c != "off"}
               for t, row in report["topologies"].items()}
    print(json.dumps(summary))
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: short run, skip throughput timing")
    ap.add_argument("--full", action="store_true", help="longer runs")
    ap.add_argument("--wall-clock", action="store_true",
                    help="timed leg: steps/sec per codec x backend, with "
                         "the identity-overhead and legacy-beating bounds "
                         "asserted")
    ap.add_argument("--out", default="BENCH_comms.json")
    args = ap.parse_args()
    main(quick=not args.full, out=args.out, measure=not args.smoke,
         wall_clock=args.wall_clock)
