"""Population-regime benchmark: virtual-client sampling cost vs population.

The population layer's contract is that a round costs O(k) — k = topology.n
active slots — no matter how many virtual clients stand behind it.  This
benchmark sweeps the declared population 10^3..10^6 over a fixed k=8
two-level topology and records, per population size:

* wall time per training step through the sampled loop (hydrate + G inner
  steps + fold-back) vs the materialized n=k baseline engine running the
  same steps — their ratio is the *population overhead* (hydrate/fold/draw);
* the hydrated (k, ...) state bytes — **asserted identical across the whole
  sweep and equal to the baseline's**, the deterministic proof that peak
  state memory is bounded by k, not the population;
* the host-side draw time and the sampled-clients ledger size.

Deterministic CI assertions (the repo's jaxpr-not-wall-clock rule: numbers
ride along, proofs don't time anything):

* state bytes are population-independent (above);
* with cells == group_sizes (k == population) and uniform weights, the
  sampled loop's server params are BITWISE the baseline engine's global
  mean — fold-back IS the level-1 sync;
* ``--backend both`` additionally runs one sweep point through the
  shard_map backend in exact mode and asserts the server params are
  bitwise the sim loop's (needs 8 devices).

Emits ``BENCH_population.json``; the CI legs run ``--smoke`` (1-device leg:
sim; 8-device leg: ``--backend both``) and upload it.

    PYTHONPATH=src python benchmarks/bench_population.py [--smoke]
        [--backend sim|mesh|both] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core import EngineConfig, HSGD, HierarchySpec, make_topology
from repro.data import PopulationShards
from repro.models import SimpleConfig, SimpleModel
from repro.obs import SCHEMA_VERSION
from repro.optim import sgd
from repro.population import Population

GS, PERIODS = (2, 4), (4, 2)     # k = 8 slots, G = 4 steps per round
K = 8
DIM, CLASSES, HIDDEN, BS = 24, 10, 32, 10
LR = 0.08
SEED = 11

# population sweep: per-level cell fanouts, 10^3 .. 10^6 virtual clients
SWEEP = {
    1_000: (10, 100),
    10_000: (100, 100),
    100_000: (100, 1_000),
    1_000_000: (1_000, 1_000),
}


def make_world():
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=DIM,
                                     hidden=HIDDEN, num_classes=CLASSES))
    shards = PopulationShards(population=max(SWEEP), num_classes=CLASSES,
                              dim=DIM, seed=SEED)
    return model, shards


def state_bytes(tree) -> int:
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(tree))


def tree_equal(a, b):
    return all(jax.tree.leaves(jax.tree.map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b)))


def make_mesh_executor():
    from repro.core import MeshExecutor
    from repro.launch.mesh import make_host_mesh
    return MeshExecutor(make_host_mesh(group_sizes=GS), exact=True)


def batch_fn(shards):
    return lambda ids, t: jax.tree.map(
        jnp.asarray, shards.batch(np.asarray(ids) % max(SWEEP), t, BS))


def bench_baseline(model, shards, rounds: int):
    """The materialized n=k engine on the same steps — the denominator of
    the population-overhead ratio, and the state-bytes reference."""
    eng = HSGD(model.loss, sgd(LR), make_topology("uniform",
                                                  spec=HierarchySpec(GS,
                                                                     PERIODS)),
               EngineConfig())
    st = eng.init(jax.random.PRNGKey(0), model.init)
    bf = batch_fn(shards)
    batch = lambda t: bf(np.arange(K), t)
    T = rounds * PERIODS[0]
    st, _ = eng.run_rounds(st, batch, T)       # warmup: compile every round
    jax.block_until_ready(st.params)
    t0 = time.time()
    st, _ = eng.run_rounds(st, batch, T)
    jax.block_until_ready(st.params)
    dt = time.time() - t0
    return {"time_per_step_s": round(dt / T, 6),
            "state_bytes": state_bytes((st.params, st.opt_state))}, st


def bench_population(model, shards, cells, rounds: int, executor=None):
    eng = HSGD(model.loss, sgd(LR),
               make_topology("uniform", spec=HierarchySpec(GS, PERIODS)),
               EngineConfig(executor=executor,
                            population=Population(cells=cells, seed=SEED)))
    popeng = eng.population_engine()
    server = eng.init_server(jax.random.PRNGKey(0), model.init)
    hydrated = popeng.hydrate(server)
    sb = state_bytes((hydrated.params, hydrated.opt_state))

    t0 = time.time()
    draws = [popeng.sampler.draw(r) for r in range(rounds)]
    draw_s = time.time() - t0
    assert all(d.client_ids.size == K for d in draws)

    bf = batch_fn(shards)
    T = rounds * PERIODS[0]
    server, _ = eng.run_sampled(server, bf, rounds)   # warmup + compile
    jax.block_until_ready(server.params)
    t0 = time.time()
    server, hist = eng.run_sampled(server, bf, rounds)
    jax.block_until_ready(server.params)
    dt = time.time() - t0
    return {"cells": list(cells),
            "time_per_step_s": round(dt / T, 6),
            "draw_ms_per_round": round(1e3 * draw_s / rounds, 4),
            "state_bytes": sb,
            "unique_clients": hist[-1]["participation"]["unique"]}, server


def main(quick: bool = True, out: str = "BENCH_population.json",
         backend: str = "sim") -> dict:
    mesh = backend in ("mesh", "both")
    if mesh and len(jax.devices()) < 8:
        raise SystemExit(
            "--backend mesh needs 8 devices: export "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax "
            "initializes (the CI 8-device leg does)")
    model, shards = make_world()
    rounds = 2 if quick else 8
    base, base_st = bench_baseline(model, shards, rounds)
    report = {"schema_version": SCHEMA_VERSION, "k": K,
              "group_sizes": list(GS), "periods": list(PERIODS),
              "rounds": rounds, "backend": backend, "baseline": base,
              "sweep": {}}

    for popsize, cells in SWEEP.items():
        print(f"... population {popsize} (cells {cells})")
        rec, _ = bench_population(model, shards, cells, rounds)
        rec["overhead_vs_baseline"] = round(
            rec["time_per_step_s"] / base["time_per_step_s"], 4)
        report["sweep"][str(popsize)] = rec

    # deterministic proof 1: peak state memory is bounded by k — identical
    # across a 1000x population sweep, and exactly the baseline's
    sizes = {r["state_bytes"] for r in report["sweep"].values()}
    assert sizes == {base["state_bytes"]}, (sizes, base["state_bytes"])

    # deterministic proof 2: cells == group_sizes (k == population) with
    # uniform weights is BITWISE the materialized engine (fold-back IS the
    # level-1 sync).  Rebuild the baseline trajectory to compare end states.
    eng = HSGD(model.loss, sgd(LR),
               make_topology("uniform", spec=HierarchySpec(GS, PERIODS)),
               EngineConfig(population=Population(cells=GS, seed=SEED)))
    server = eng.init_server(jax.random.PRNGKey(0), model.init)
    server, _ = eng.run_sampled(server, batch_fn(shards), 2 * rounds)
    beng = HSGD(model.loss, sgd(LR),
                make_topology("uniform", spec=HierarchySpec(GS, PERIODS)),
                EngineConfig())
    bst = beng.init(jax.random.PRNGKey(0), model.init)
    bf = batch_fn(shards)
    bst, _ = beng.run_rounds(bst, lambda t: bf(np.arange(K), t),
                             2 * rounds * PERIODS[0])
    row0 = jax.tree.map(lambda x: np.asarray(x)[0], bst.params)
    assert tree_equal(row0, server.params), \
        "k == population sampled loop diverged from the materialized engine"
    report["bitwise_k_eq_population"] = True

    if mesh:
        # deterministic proof 3: the mesh backend (exact mode) runs the
        # sampled loop bitwise-identical to sim — same draws, same fold
        cells = SWEEP[1_000_000]
        rec_sim, srv_sim = bench_population(model, shards, cells, rounds)
        rec_mesh, srv_mesh = bench_population(model, shards, cells, rounds,
                                              executor=make_mesh_executor())
        assert tree_equal(srv_sim.params, srv_mesh.params), \
            "mesh(exact) sampled loop diverged from sim"
        report["mesh"] = dict(rec_mesh, backend="mesh(exact)",
                              params_bitwise_vs_sim=True)

    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out}")
    summary = {p: r["overhead_vs_baseline"]
               for p, r in report["sweep"].items()}
    print(json.dumps({"overhead_vs_baseline": summary}))
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: fewer rounds (the memory/bitwise proofs "
                         "are deterministic either way; only the recorded "
                         "timings get noisier)")
    ap.add_argument("--full", action="store_true", help="longer runs")
    ap.add_argument("--backend", default="sim",
                    choices=["sim", "mesh", "both"],
                    help="'mesh'/'both' additionally runs the 10^6 sweep "
                         "point through the shard_map backend (exact mode) "
                         "and asserts the server params are bitwise the sim "
                         "loop's (needs 8 devices)")
    ap.add_argument("--out", default="BENCH_population.json")
    args = ap.parse_args()
    main(quick=args.smoke or not args.full, out=args.out,
         backend=args.backend)
