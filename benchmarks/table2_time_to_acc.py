"""Table 2 / Fig. 2: time-to-target-accuracy under the paper's measured
communication model (Table E.1) — H-SGD reaches the target in a fraction of
local SGD's wall-clock because global (far) rounds are rare."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (comm_time_ms, make_world, mean_trajectories,
                               time_to_target)
from repro.core import local_sgd, make_topology, two_level

N_WORKERS = 8


def main(quick: bool = True):
    T = 120 if quick else 300
    ds, model = make_world(N_WORKERS)
    seeds = (0, 1, 2) if quick else tuple(range(6))

    configs = {
        "P=4": local_sgd(N_WORKERS, 4),
        "P=16": local_sgd(N_WORKERS, 16),
        "G=16,I=4": two_level(N_WORKERS, 2, 16, 4),
        "G=64,I=2": two_level(N_WORKERS, 2, 64, 2),
    }
    target = 0.75
    rows = []
    for name, spec in configs.items():
        hist = mean_trajectories(ds, model,
                                 lambda s=spec: make_topology(s), T,
                                 seeds=seeds, eval_every=4)
        t_ms = time_to_target(hist, spec, target, model_kind="cnn")
        total_ms = comm_time_ms(spec, T, "cnn")
        rows.append({"config": name, "final_acc": hist[-1]["acc"],
                     "time_to_75%_ms": t_ms, "total_ms_at_T": total_ms})
    print(f"# Table 2 — time (ms) to {target:.0%} accuracy "
          "(comm model: Table E.1 CNN near=0.29ms far=4.53ms, 4ms/iter)")
    print("config,final_acc,time_to_target_ms,total_ms")
    for r in rows:
        print(f"{r['config']},{r['final_acc']:.4f},"
              f"{r['time_to_75%_ms']},{r['total_ms_at_T']:.1f}")
    by = {r["config"]: r for r in rows}
    # H-SGD must reach target no slower than the comparable local SGD P=4
    if by["P=4"]["time_to_75%_ms"] and by["G=16,I=4"]["time_to_75%_ms"]:
        assert (by["G=16,I=4"]["time_to_75%_ms"]
                <= by["P=4"]["time_to_75%_ms"] * 1.05)
    return rows


if __name__ == "__main__":
    main()
