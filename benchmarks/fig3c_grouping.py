"""Fig. 3c: grouping effects — group-IID (upward divergence ~0) vs
group-non-IID, plus the measured divergences that explain the gap."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_world, mean_trajectories
from repro.core import (all_divergences, diversity_grouping, group_iid,
                        group_noniid, make_topology, per_worker_grads)

N_WORKERS = 8


def main(quick: bool = True):
    T = 96 if quick else 240
    G, I = 16, 4
    # 4 classes over 8 workers (each label on 2 workers) so that a
    # label-balanced 'group-IID' grouping exists (paper Fig 3c construction)
    ds, model = make_world(N_WORKERS, num_classes=4)
    labels = ds.dominant_labels()
    seeds = (0, 1, 2) if quick else tuple(range(6))

    g_iid = group_iid(labels, 2)
    g_non = group_noniid(labels, 2)

    iid = mean_trajectories(ds, model, lambda: make_topology("grouped", grouping=g_iid, G=G, I=I),
                            T, seeds=seeds)[-1]
    non = mean_trajectories(ds, model, lambda: make_topology("grouped", grouping=g_non, G=G, I=I),
                            T, seeds=seeds)[-1]
    # Fig 3c second claim: group-IID ~ group-non-IID with I halved
    non_i2 = mean_trajectories(ds, model,
                               lambda: make_topology("grouped", grouping=g_non, G=G, I=I // 2),
                               T, seeds=seeds)[-1]

    # measured divergences at w0 (the mechanism)
    params0 = model.init(jax.random.PRNGKey(0))
    grads = per_worker_grads(model.loss, params0,
                             jax.tree.map(jnp.asarray, ds.full_per_worker(64)))
    div_iid = all_divergences(grads, g_iid)
    div_non = all_divergences(grads, g_non)

    # Remark 2, operationalized: build the grouping from MEASURED gradients
    # (no label oracle) — should recover ~group-IID quality
    g_auto = diversity_grouping(np.asarray(grads), 2)
    div_auto = all_divergences(grads, g_auto)
    auto = mean_trajectories(ds, model,
                             lambda: make_topology("grouped", grouping=g_auto, G=G, I=I),
                             T, seeds=seeds)[-1]

    print(f"# Fig 3c — grouping (T={T})")
    print("config,loss,acc,upward_div,downward_div")
    print(f"group-IID,{iid['loss']:.4f},{iid['acc']:.4f},"
          f"{div_iid['upward']:.3f},{div_iid['downward_avg']:.3f}")
    print(f"group-nonIID,{non['loss']:.4f},{non['acc']:.4f},"
          f"{div_non['upward']:.3f},{div_non['downward_avg']:.3f}")
    print(f"group-nonIID_I{I//2},{non_i2['loss']:.4f},{non_i2['acc']:.4f},,")
    print(f"diversity(measured-grads),{auto['loss']:.4f},{auto['acc']:.4f},"
          f"{div_auto['upward']:.3f},{div_auto['downward_avg']:.3f}")
    assert div_iid["upward"] < 0.1 * div_non["upward"]
    assert iid["loss"] <= non["loss"] + 0.02
    # the measured-gradient grouping must land near the label-oracle one
    assert div_auto["upward"] < 0.5 * div_non["upward"]
    assert auto["loss"] <= non["loss"] + 0.02
    return {"iid": iid["loss"], "non": non["loss"], "auto": auto["loss"],
            "upward_iid": div_iid["upward"], "upward_non": div_non["upward"],
            "upward_auto": div_auto["upward"]}


if __name__ == "__main__":
    main()
