"""Table 1: numeric comparison of convergence bounds across the literature.

Evaluates every row's O-expression (unit constants) on a grid and reports the
fraction of the grid where ours is the tightest applicable bound, plus the
paper's three headline comparisons (vs Yu'19 general, vs Liu'20 at sigma=0,
vs Castiglia'21 at eps=0)."""
from __future__ import annotations

import itertools

import numpy as np

from repro.core import theory as th


def rows(quick: bool = True):
    ns = [16, 64] if quick else [16, 32, 64, 128]
    Ts = [2_000, 20_000]
    GIs = [(20, 5), (50, 5), (50, 10)]
    s2e2 = [(1.0, 1.0), (0.5, 2.0)]
    out = []
    wins_yu = wins_liu = wins_cast = total = 0
    for n, T, (G, I), (s2, e2) in itertools.product(ns, Ts, GIs, s2e2):
        N = max(2, n // 8)
        ours = th.table1_ours(n, N, T, G, I, s2, e2)
        yu = th.table1_yu2019(n, T, G, s2, e2)
        cast = th.table1_castiglia2021(n, T, G, I, s2)
        ours_s0 = th.table1_ours(n, N, T, G, I, 0.0, e2)
        liu_s0 = th.table1_liu2020(n, T, G, e2)
        ours_e0 = th.table1_ours(n, N, T, G, I, s2, 0.0)
        total += 1
        wins_yu += ours < yu
        wins_liu += ours_s0 < liu_s0
        wins_cast += ours_e0 < cast
        out.append({"n": n, "N": N, "T": T, "G": G, "I": I,
                    "sigma2": s2, "eps2": e2,
                    "ours": ours, "yu2019": yu,
                    "ours_sigma0": ours_s0, "liu2020_sigma0": liu_s0,
                    "ours_eps0": ours_e0, "castiglia2021_eps0": cast})
    summary = {"grid_points": total,
               "ours_tighter_than_yu2019": wins_yu / total,
               "ours_tighter_than_liu2020": wins_liu / total,
               "ours_tighter_than_castiglia2021": wins_cast / total}
    return out, summary


def main(quick: bool = True):
    table, summary = rows(quick)
    print("# Table 1 — bound comparison (unit-constant O-expressions)")
    hdr = list(table[0].keys())
    print(",".join(hdr))
    for r in table[:8]:
        print(",".join(f"{r[k]:.4g}" if isinstance(r[k], float) else str(r[k])
                       for k in hdr))
    print("summary:", summary)
    assert summary["ours_tighter_than_yu2019"] == 1.0
    assert summary["ours_tighter_than_liu2020"] == 1.0
    assert summary["ours_tighter_than_castiglia2021"] == 1.0
    return summary


if __name__ == "__main__":
    main()
