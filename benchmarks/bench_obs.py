"""Observability overhead benchmark: the probe's R6 contract, wall-clock.

``repro.obs`` promises that the in-graph divergence/grad-norm probes are
cheap enough to leave on: no host callbacks or transfers in the round body
(rules R3/R6 pin that statically) and a bounded handful of extra in-graph
reduces.  This benchmark makes the wall-clock side of that promise
concrete: the schedule-compiled round executor is timed with
``metrics="on"`` vs ``metrics=None`` on a 2-level and a 3-level hierarchy,
and the JSON records both rates plus their ratio.  The timed leg is the
SIM executor only — it is the paper-experiment throughput path, and the
repo never gates on host-emulated mesh wall-clock (DESIGN.md §2.4's
jaxpr-not-wall-clock rule; tiny per-level collectives on a host mesh time
the emulation, not the probe).  The mesh probe rides the static leg: its
op counts are audited here for every backend the device count allows.

Asserted at generation time (the bound the CI smoke enforces): probes-on
reaches at least 95% of probes-off steps/sec on the best SAME-REP pairing.
Every repeat times both variants back-to-back, so each pairing samples the
same machine state; the best pairing discards repeats that landed in a slow
phase of this box's ~20% throughput jitter.  The static side rides along:
the engine audit's ``probes`` block (extra ops vs the metrics-off twin) is
re-asserted against ``Metrics.op_budget`` here, so the JSON carries the
measured op counts next to the measured rates.

Emits ``BENCH_obs.json``
(schema: {topology: {off, on, ratio_best_pair, probes: {backend: ...}}}).
The CI smoke step runs ``--smoke`` on both device legs and uploads it as an
artifact.

    PYTHONPATH=src python benchmarks/bench_obs.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp

# runnable both as `python -m benchmarks.bench_obs` and as a plain script
# (`python benchmarks/bench_obs.py`, the CI smoke invocation)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import steps_per_sec  # noqa: E402
from repro.core import HSGD, HierarchySpec, make_topology
from repro.data import (FederatedDataset, label_shard_partition,  # noqa: E402
                        make_classification)
from repro.models import SimpleConfig, SimpleModel  # noqa: E402
from repro.optim import sgd

TOPOLOGIES = {
    "two_level": HierarchySpec((2, 4), (32, 8)),
    "three_level": HierarchySpec((2, 2, 2), (32, 16, 8)),
}

# every repeat times off/on back-to-back; each variant keeps its per-rep
# rate so the assertion can pick the best SAME-REP ratio (see module doc)
REPEATS = 3
MIN_RATIO = 0.95
# the contract is stated for training steps with real compute: a wide MLP
# and a batch per worker big enough that the grad step dominates the
# probes (the divergence row is ~a pass over the params per sync, the
# grad-norm channel ~a pass over the grads per step — both memory-bound,
# so they amortize only against real compute).  The paper-scale periods
# above (inner sync every 8 steps) amortize the divergence probe the same
# way real runs do.
BATCH = 512
DIM, HIDDEN, CLASSES = 64, 256, 8


def make_obs_world(n_workers: int = 8, seed: int = 3):
    x, y = make_classification(seed, num_classes=CLASSES, dim=DIM,
                               per_class=160, spread=1.5)
    parts = label_shard_partition(
        y, [[j % CLASSES] for j in range(n_workers)])
    ds = FederatedDataset(x, y, parts)
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=DIM,
                                     hidden=HIDDEN, num_classes=CLASSES))
    return ds, model


def probe_op_leg(spec: HierarchySpec, backend: str) -> dict:
    """Static leg: audit the metrics-on engine and return its ``probes``
    block (extra ops / callbacks / transfers per round vs the metrics-off
    twin), asserting the op budget and the zero-host-cost contract."""
    topo = make_topology("uniform", spec=spec)
    from repro.models import SimpleConfig, SimpleModel
    model = SimpleModel(SimpleConfig(kind="mlp", input_dim=16, hidden=8,
                                     num_classes=4))
    eng = HSGD(model.loss, sgd(0.08), topo, executor=backend, metrics="on")
    state = eng.init(jax.random.PRNGKey(0), model.init)
    n = topo.n

    def batch_fn(t):
        x = jax.random.normal(jax.random.PRNGKey(t), (n, 4, 16))
        return {"x": x, "y": jnp.zeros((n, 4), jnp.int32)}

    report = eng.audit(state, batch_fn=batch_fn, run=False)
    probes = report.probes
    assert probes is not None
    for key, d in probes["rounds"].items():
        assert d["extra_callbacks"] == 0 and d["extra_transfers"] == 0, \
            (key, d)
        assert d["extra_ops"] <= probes["budget"], (key, d, probes["budget"])
    return probes


def bench_topology(ds, model, spec: HierarchySpec, T: int,
                   backends) -> dict:
    # wall-clock ALWAYS times the sim executor: it is the paper-experiment
    # throughput path, and the repo's verification rule (DESIGN.md §2.4)
    # forbids gating on host-emulated mesh wall-clock — tiny per-level
    # collectives there measure the emulation, not the probe.  The mesh
    # probe's cost is pinned statically instead (probe_op_leg below, and
    # the mesh probes config of the analysis budget).
    runs = {"off": [], "on": []}
    for rep in range(REPEATS):
        for name, metrics in (("off", None), ("on", "on")):
            topo = make_topology("uniform", spec=spec)
            runs[name].append(steps_per_sec(
                ds, model, topo, T=T, bs=BATCH, use_rounds=True,
                warmup=spec.G, backend="sim", metrics=metrics))
        print(f"... rep {rep}: off={runs['off'][-1]:.0f} "
              f"on={runs['on'][-1]:.0f} steps/s")
    pairs = [on / off for on, off in zip(runs["on"], runs["off"])]
    rec = {
        "off": {"steps_per_sec_best": round(max(runs["off"]), 2),
                "steps_per_sec_all": [round(x, 2) for x in runs["off"]]},
        "on": {"steps_per_sec_best": round(max(runs["on"]), 2),
               "steps_per_sec_all": [round(x, 2) for x in runs["on"]]},
        "ratio_best_pair": round(max(pairs), 4),
        "ratio_all": [round(r, 4) for r in pairs],
        "probes": {b: probe_op_leg(spec, b) for b in backends},
    }
    # the overhead contract: probes-on within 5% of probes-off on the best
    # same-rep pairing
    assert rec["ratio_best_pair"] >= MIN_RATIO, rec
    return rec


def main(quick: bool = True, out: str = "BENCH_obs.json") -> dict:
    ds, model = make_obs_world(n_workers=8)
    T = 64 if quick else 256
    backends = ["sim"]
    if len(jax.devices()) >= 8:
        backends.append("mesh")
    report = {"steps": T, "repeats": REPEATS, "timed_backend": "sim",
              "audited_backends": backends, "min_ratio": MIN_RATIO,
              "topologies": {}}
    for tname, spec in TOPOLOGIES.items():
        print(f"... {tname} (timed: sim; audited: {'+'.join(backends)})")
        report["topologies"][tname] = bench_topology(
            ds, model, spec, T, backends)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out}")
    summary = {t: row["ratio_best_pair"]
               for t, row in report["topologies"].items()}
    print(json.dumps({"probe_overhead_ratio": summary}))
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: short timed run (the 5%% overhead bound "
                         "is still asserted — it uses the best same-rep "
                         "pairing, which tolerates this box's jitter)")
    ap.add_argument("--full", action="store_true", help="longer runs")
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args()
    main(quick=not args.full, out=args.out)
