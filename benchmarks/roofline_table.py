"""Roofline table (§Roofline deliverable): renders benchmarks/results/
dryrun.json into the per-(arch x shape x mesh) three-term table."""
from __future__ import annotations

import json
import os
from typing import Dict, List

HBM_PER_CHIP = 16e9  # v5e


def load(path: str = "benchmarks/results/dryrun.json") -> Dict:
    with open(path) as f:
        return json.load(f)


def rows(results: Dict) -> List[Dict]:
    out = []
    for key, rec in sorted(results.items()):
        arch, shape, mesh = key.split("|")
        steps = rec["steps"]
        head_name = "global_sync" if "global_sync" in steps else \
            next(iter(steps))
        head = steps[head_name]
        peak = head.get("peak_memory_bytes") or 0
        row = {
            "arch": arch, "shape": shape, "mesh": mesh,
            "mapping": rec.get("mapping") or "-",
            "n_workers": rec.get("n_workers") or "-",
            "compute_s": rec["terms_s"]["compute"],
            "memory_s": rec["terms_s"]["memory"],
            "collective_s": rec["terms_s"]["collective"],
            "dominant": rec["dominant"],
            "useful_ratio": rec.get("useful_ratio", 0.0),
            "peak_gb": peak / 1e9,
            "fits_hbm": peak <= HBM_PER_CHIP,
        }
        if "amortized" in rec:
            row["amortized_dominant"] = rec["amortized"]["dominant"]
        out.append(row)
    return out


def main(quick: bool = True, path: str = "benchmarks/results/dryrun.json"):
    if not os.path.exists(path):
        print(f"(roofline) no dry-run cache at {path}; run "
              "`python -m repro.launch.dryrun` first")
        return []
    rs = rows(load(path))
    cols = ["arch", "shape", "mesh", "mapping", "dominant", "compute_s",
            "memory_s", "collective_s", "useful_ratio", "peak_gb", "fits_hbm"]
    print("# Roofline table (per chip, v5e constants; decode/prefill = one "
          "serve step, train = global-sync step)")
    print(",".join(cols))
    for r in rs:
        print(",".join(
            f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
            for c in cols))
    doms = {}
    for r in rs:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print("dominant-term histogram:", doms)
    return rs


if __name__ == "__main__":
    main()
